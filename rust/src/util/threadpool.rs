//! Fixed-size worker thread pool — the substrate for the paper's inner-layer
//! multi-threaded parallelism (§4.2).
//!
//! Two usage modes:
//! * [`ThreadPool::execute`] — fire-and-forget jobs on a shared queue
//!   (classic work queue; used by generic parallel helpers).
//! * [`ThreadPool::execute_on`] — pin a job to a *specific* worker. The
//!   paper's Algorithm 4.2 assigns each task to the thread with minimal
//!   workload, which requires per-thread queues; the inner-layer scheduler
//!   builds on this mode.
//!
//! Wakeup is condvar-based: idle workers park on a per-worker condvar and a
//! job post wakes exactly the worker(s) that can run it. There is no poll
//! loop — an idle pool consumes zero CPU, and a job posted into an idle pool
//! starts within a thread-wakeup (microseconds, not the old 1 ms
//! `recv_timeout` poll interval).
//!
//! Every worker additionally owns a persistent [`ScratchArena`]: growable
//! buffers that survive across tasks, so hot task bodies (conv row tiles,
//! gradient tiles) never allocate. Tasks pinned to worker `i` via
//! [`ThreadPool::execute_on`] may lock `arena(i)` uncontended — only worker
//! `i` runs pinned jobs, and it runs them one at a time.

use std::collections::VecDeque;

// Under `--cfg loom` (the model-checking lane in sanitizers.yml) the pool's
// sleep/wake protocol runs on loom's instrumented sync primitives so every
// interleaving of park/post/shutdown is explored. The per-worker arenas stay
// on `std::sync` — they are plain data handed out under a lock, not part of
// the protocol, and callers outside this module name their types as std.
#[cfg(loom)]
use loom::sync::atomic::{AtomicUsize, Ordering};
#[cfg(loom)]
use loom::sync::{Arc, Condvar, Mutex};
#[cfg(loom)]
use loom::thread::JoinHandle;
#[cfg(not(loom))]
use std::sync::atomic::{AtomicUsize, Ordering};
#[cfg(not(loom))]
use std::sync::{Arc, Condvar, Mutex, OnceLock};
#[cfg(not(loom))]
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Persistent per-worker scratch buffers (the paper's fine-grained tasks only
/// pay for allocation once, then reuse — see ISSUE 2 / Dryden et al. on
/// driving per-task overhead to zero).
///
/// Buffers only ever grow; contents between tasks are *unspecified* (a task
/// must fully overwrite — or [`ScratchArena::grow_zeroed`] — every region it
/// reads). The conv engine uses:
/// * `cols` — im2col patch tiles,
/// * `cols2` — second patch tile (backward-input over `dy`),
/// * `grad_f` / `grad_b` — per-worker partial filter/bias gradients,
///   accumulated across all tasks a worker runs for one layer call and
///   reduced once at the end (no mutex in the task body).
///
/// Contract: one task-parallel layer call owns the pool's arenas at a time
/// (the inner-layer scheduler runs layer calls back-to-back, never
/// concurrently on one pool).
#[derive(Default)]
pub struct ScratchArena {
    pub cols: Vec<f32>,
    pub cols2: Vec<f32>,
    pub grad_f: Vec<f32>,
    pub grad_b: Vec<f32>,
}

impl ScratchArena {
    /// Ensure `buf` holds at least `len` elements and return the `len`-prefix.
    /// Contents of the returned slice are unspecified (may hold data from a
    /// previous task) — callers must overwrite everything they read.
    pub fn grow(buf: &mut Vec<f32>, len: usize) -> &mut [f32] {
        if buf.len() < len {
            buf.resize(len, 0.0);
        }
        &mut buf[..len]
    }

    /// Like [`ScratchArena::grow`] but the returned prefix is zeroed.
    pub fn grow_zeroed(buf: &mut Vec<f32>, len: usize) -> &mut [f32] {
        let s = Self::grow(buf, len);
        s.fill(0.0);
        s
    }

    /// Checked handout of a full `len`-element accumulator that the layer
    /// call already sized with [`ScratchArena::grow_zeroed`]. Unlike `grow`
    /// this never resizes: a task asking for more than the plan provisioned
    /// is a scheduling bug and panics instead of reallocating mid-flight.
    pub fn grad_all(buf: &mut Vec<f32>, len: usize) -> &mut [f32] {
        assert!(buf.len() >= len, "arena handout of {len} from {}-element buffer", buf.len());
        &mut buf[..len]
    }

    /// Checked handout of the `[j0, j0+jw)` column stripe of an
    /// `n`-element accumulator (a tile's private window of `grad_b`).
    pub fn grad_stripe(buf: &mut Vec<f32>, n: usize, j0: usize, jw: usize) -> &mut [f32] {
        let end = j0.checked_add(jw).expect("arena stripe overflows usize");
        assert!(end <= n && n <= buf.len(), "stripe [{j0}, {end}) outside {n}/{}", buf.len());
        &mut buf[j0..end]
    }

    /// Checked base pointer for a strided `kk × [j0, j0+jw)` column window
    /// of a row-major `kk × n` accumulator (fed to
    /// [`crate::nn::ops::gemm_tn_acc_cols_raw`], which cannot take a slice:
    /// the window is non-contiguous). Validates the window geometry against
    /// the grown buffer before surrendering the pointer.
    pub fn grad_window_ptr(
        buf: &mut Vec<f32>,
        kk: usize,
        n: usize,
        j0: usize,
        jw: usize,
    ) -> *mut f32 {
        let end = j0.checked_add(jw).expect("arena window overflows usize");
        let total = kk.checked_mul(n).expect("arena window overflows usize");
        assert!(end <= n && total <= buf.len(), "window {kk}x[{j0}, {end}) outside buffer");
        buf.as_mut_ptr()
    }
}

/// All job queues, guarded by one mutex (held only for queue push/pop, never
/// while a job runs).
struct Queues {
    shared: VecDeque<Job>,
    private: Vec<VecDeque<Job>>,
    /// `sleeping[i]` ⇔ worker `i` is parked on `worker_cvs[i]`.
    sleeping: Vec<bool>,
    shutdown: bool,
}

struct Shared {
    queues: Mutex<Queues>,
    /// One condvar per worker (all paired with the `queues` mutex), so a
    /// private-queue post wakes exactly its worker and a shared-queue post
    /// wakes exactly one sleeper — no thundering herd, no poll interval.
    worker_cvs: Vec<Condvar>,
    /// Jobs currently queued or running, for `wait_idle`.
    inflight: AtomicUsize,
    idle: Mutex<()>,
    idle_cv: Condvar,
}

/// A pool of worker threads with one queue per worker plus a shared queue.
pub struct ThreadPool {
    shared: Arc<Shared>,
    arenas: Vec<std::sync::Arc<std::sync::Mutex<ScratchArena>>>,
    handles: Vec<JoinHandle<()>>,
    /// Cached [`ThreadPool::dispatch_overhead_s`] measurement (calibration
    /// hook for the inner-layer autotuner). Absent under loom: the model
    /// has no wall clock, so the probe cannot run there.
    #[cfg(not(loom))]
    dispatch_overhead: OnceLock<f64>,
}

/// Spawn worker `i`'s OS (or loom-modeled) thread.
#[cfg(not(loom))]
fn spawn_worker(i: usize, shared: Arc<Shared>) -> JoinHandle<()> {
    std::thread::spawn(move || worker_loop(i, shared))
}

#[cfg(loom)]
fn spawn_worker(i: usize, shared: Arc<Shared>) -> JoinHandle<()> {
    loom::thread::spawn(move || worker_loop(i, shared))
}

impl ThreadPool {
    /// Spawn `n` workers (`n >= 1`).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "thread pool needs at least one worker");
        let shared = Arc::new(Shared {
            queues: Mutex::new(Queues {
                shared: VecDeque::new(),
                private: (0..n).map(|_| VecDeque::new()).collect(),
                sleeping: vec![false; n],
                shutdown: false,
            }),
            worker_cvs: (0..n).map(|_| Condvar::new()).collect(),
            inflight: AtomicUsize::new(0),
            idle: Mutex::new(()),
            idle_cv: Condvar::new(),
        });
        let handles = (0..n).map(|i| spawn_worker(i, Arc::clone(&shared))).collect();
        let arenas = (0..n)
            .map(|_| std::sync::Arc::new(std::sync::Mutex::new(ScratchArena::default())))
            .collect();
        Self {
            shared,
            arenas,
            handles,
            #[cfg(not(loom))]
            dispatch_overhead: OnceLock::new(),
        }
    }

    pub fn size(&self) -> usize {
        self.handles.len()
    }

    /// Measured per-task dispatch + wakeup overhead of this pool in
    /// seconds, probed once on first use and cached — the calibration hook
    /// the inner-layer autotuner derives its per-tile FLOP floor from
    /// (`crate::inner::autotune::Calibration`).
    #[cfg(not(loom))]
    pub fn dispatch_overhead_s(&self) -> f64 {
        *self.dispatch_overhead.get_or_init(|| self.probe_dispatch_overhead())
    }

    /// Loom models have no wall clock; report a fixed plausible estimate so
    /// callers compile unchanged under `--cfg loom`.
    #[cfg(loom)]
    pub fn dispatch_overhead_s(&self) -> f64 {
        5e-6
    }

    /// The probe behind [`ThreadPool::dispatch_overhead_s`]: posts bursts
    /// of trivial pinned jobs (the Algorithm-4.2 dispatch path) and times
    /// queue push + wakeup + completion per job, taking the fastest rep so
    /// a scheduler hiccup cannot inflate the estimate. The pool must be
    /// otherwise idle.
    #[cfg(not(loom))]
    pub fn probe_dispatch_overhead(&self) -> f64 {
        const JOBS: usize = 128;
        const REPS: usize = 4;
        // Warm: make sure every worker has run at least one job.
        for w in 0..self.size() {
            self.execute_on(w, || {});
        }
        self.wait_idle();
        let mut best = f64::INFINITY;
        for _ in 0..REPS {
            let t0 = std::time::Instant::now();
            for j in 0..JOBS {
                self.execute_on(j % self.size(), || {});
            }
            self.wait_idle();
            best = best.min(t0.elapsed().as_secs_f64() / JOBS as f64);
        }
        best.max(1e-9)
    }

    /// Worker `i`'s persistent scratch arena. Lock it from a job pinned to
    /// worker `i` (uncontended by construction) or from the submitting thread
    /// after [`ThreadPool::wait_idle`] (e.g. to reduce per-worker partials).
    pub fn arena(&self, i: usize) -> &std::sync::Arc<std::sync::Mutex<ScratchArena>> {
        &self.arenas[i]
    }

    /// All per-worker arenas, indexed by worker.
    pub fn arenas(&self) -> &[std::sync::Arc<std::sync::Mutex<ScratchArena>>] {
        &self.arenas
    }

    /// Queue a job on the shared queue (any worker picks it up).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.push_job(None, Box::new(job));
    }

    /// Queue a job on worker `i`'s private queue (Algorithm 4.2 assignment).
    pub fn execute_on<F: FnOnce() + Send + 'static>(&self, i: usize, job: F) {
        assert!(i < self.size());
        self.push_job(Some(i), Box::new(job));
    }

    /// Queue a job that borrows non-`'static` data on worker `i`'s private
    /// queue. This is what lets the inner-layer dispatch be zero-copy: conv
    /// tasks borrow the caller's activation/filter/gradient tensors directly
    /// instead of `Arc::from` copies.
    ///
    /// # Safety
    /// The caller must guarantee the job has *finished running* before any
    /// data it borrows is moved or freed — including when the caller unwinds.
    /// [`crate::inner::execute_dag`] upholds this with a completion guard
    /// that blocks until every dispatched job has completed.
    pub unsafe fn execute_on_borrowed<'a>(&self, i: usize, job: Box<dyn FnOnce() + Send + 'a>) {
        assert!(i < self.size());
        // SAFETY: lifetime erasure only; the caller contract above guarantees
        // the job cannot outlive its borrows.
        type BorrowedJob<'b> = Box<dyn FnOnce() + Send + 'b>;
        let job: Job = unsafe { std::mem::transmute::<BorrowedJob<'a>, BorrowedJob<'static>>(job) };
        self.push_job(Some(i), job);
    }

    fn push_job(&self, target: Option<usize>, job: Job) {
        self.shared.inflight.fetch_add(1, Ordering::SeqCst);
        let mut q = self.shared.queues.lock().unwrap();
        let wake = match target {
            Some(i) => {
                q.private[i].push_back(job);
                q.sleeping[i].then_some(i)
            }
            None => {
                q.shared.push_back(job);
                q.sleeping.iter().position(|&s| s)
            }
        };
        // Claim the chosen sleeper *now* (it only un-flags itself once it
        // actually wakes): a burst of posts then fans out across distinct
        // sleepers instead of piling onto the first one.
        if let Some(i) = wake {
            q.sleeping[i] = false;
        }
        drop(q);
        if let Some(i) = wake {
            self.shared.worker_cvs[i].notify_one();
        }
    }

    /// Block until every queued job has finished.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.idle.lock().unwrap();
        while self.shared.inflight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.idle_cv.wait(guard).unwrap();
        }
        drop(guard);
    }
}

fn worker_loop(i: usize, shared: Arc<Shared>) {
    let mut guard = shared.queues.lock().unwrap();
    loop {
        // Private queue first (pinned tasks), then the shared queue.
        let job = match guard.private[i].pop_front() {
            Some(j) => Some(j),
            None => guard.shared.pop_front(),
        };
        match job {
            Some(job) => {
                drop(guard);
                // A panicking job must not kill the worker or leak
                // `inflight` (either would wedge wait_idle / drop / the
                // scheduler barrier forever). The panic is contained here;
                // DAG tasks re-raise theirs on the dispatching thread via
                // the scheduler's own catch.
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                if shared.inflight.fetch_sub(1, Ordering::SeqCst) == 1 {
                    let _g = shared.idle.lock().unwrap();
                    shared.idle_cv.notify_all();
                }
                guard = shared.queues.lock().unwrap();
            }
            None => {
                if guard.shutdown {
                    return;
                }
                // Both queues empty: park. The `sleeping` flag is flipped
                // under the queue mutex and `Condvar::wait` releases that
                // mutex atomically, so a post can never slip between the
                // emptiness check and the park (no lost wakeups).
                guard.sleeping[i] = true;
                guard = shared.worker_cvs[i].wait(guard).unwrap();
                guard.sleeping[i] = false;
            }
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.wait_idle();
        {
            let mut q = self.shared.queues.lock().unwrap();
            q.shutdown = true;
        }
        for cv in &self.shared.worker_cvs {
            cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Run `f(i)` for `i in 0..n` across the pool and collect results in order.
pub fn parallel_map<T: Send + 'static, F>(pool: &ThreadPool, n: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Send + Sync + 'static,
{
    // Plain data plumbing, not part of the modeled protocol — std on purpose
    // so the helper compiles (unexercised) under `--cfg loom`.
    use std::sync::{Arc, Mutex};
    let f = Arc::new(f);
    let results: Arc<Mutex<Vec<Option<T>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));
    for i in 0..n {
        let f = Arc::clone(&f);
        let results = Arc::clone(&results);
        pool.execute(move || {
            let v = f(i);
            results.lock().unwrap()[i] = Some(v);
        });
    }
    pool.wait_idle();
    Arc::try_unwrap(results)
        .unwrap_or_else(|_| panic!("outstanding references"))
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("job did not run"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::mpsc::channel;
    use std::time::{Duration, Instant};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn execute_on_pins_to_worker() {
        let pool = ThreadPool::new(3);
        let ids: Arc<Mutex<Vec<std::thread::ThreadId>>> = Arc::new(Mutex::new(vec![]));
        for _ in 0..20 {
            let ids = Arc::clone(&ids);
            pool.execute_on(1, move || {
                ids.lock().unwrap().push(std::thread::current().id());
            });
        }
        pool.wait_idle();
        let ids = ids.lock().unwrap();
        assert_eq!(ids.len(), 20);
        assert!(ids.iter().all(|&id| id == ids[0]), "pinned jobs ran on several threads");
    }

    #[test]
    fn parallel_map_ordered() {
        let pool = ThreadPool::new(4);
        let out = parallel_map(&pool, 50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle(); // must not hang
    }

    #[test]
    fn drop_joins_workers() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    std::thread::sleep(Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop waits
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    /// Median start latency (µs) of `trials` jobs posted into an idle pool.
    /// Median rather than mean: robust against CI scheduler hiccups while
    /// still cleanly separating condvar wakeup (~µs) from the old 1 ms
    /// `recv_timeout` poll loop (median ≥ ~500 µs on a single worker).
    fn median_start_latency_us(
        pool: &ThreadPool,
        trials: usize,
        post: &impl Fn(&ThreadPool, std::sync::mpsc::Sender<Instant>),
    ) -> u128 {
        let mut lat: Vec<u128> = Vec::with_capacity(trials);
        for _ in 0..trials {
            // Let the workers park before each trial.
            std::thread::sleep(Duration::from_millis(2));
            let (tx, rx) = channel();
            let t0 = Instant::now();
            post(pool, tx);
            let started = rx.recv().unwrap();
            lat.push(started.saturating_duration_since(t0).as_micros());
        }
        lat.sort_unstable();
        lat[trials / 2]
    }

    /// Assert a sub-300 µs median start latency, retrying up to three
    /// measurement batches: `cargo test` runs this concurrently with other
    /// tests, so a single batch can be polluted by scheduler noise on small
    /// CI runners — only a *sustained* regression (like a poll loop, whose
    /// per-batch pass probability is < 1%) fails all three. The two latency
    /// tests also serialize against each other to halve self-interference.
    fn assert_idle_start_fast(
        pool: &ThreadPool,
        post: impl Fn(&ThreadPool, std::sync::mpsc::Sender<Instant>),
    ) {
        static LATENCY_TESTS: Mutex<()> = Mutex::new(());
        let _serial = LATENCY_TESTS.lock().unwrap_or_else(|p| p.into_inner());
        let mut medians = Vec::new();
        for _ in 0..3 {
            let med = median_start_latency_us(pool, 33, &post);
            if med < 300 {
                return;
            }
            medians.push(med);
        }
        panic!(
            "idle-pool job start latency medians {medians:?} µs, expected < 300 µs — \
             poll-based pools sit near 500 µs"
        );
    }

    /// Regression for the 1 ms `recv_timeout` poll loop: a shared-queue job
    /// posted into a fully idle (parked) pool must start in well under a
    /// millisecond. One worker so a poll-based pool cannot hide behind
    /// phase-shifted pollers.
    #[test]
    fn idle_pool_shared_job_starts_fast() {
        let pool = ThreadPool::new(1);
        pool.execute(|| {});
        pool.wait_idle();
        assert_idle_start_fast(&pool, |p, tx| {
            p.execute(move || {
                let _ = tx.send(Instant::now());
            });
        });
    }

    /// Pinned-job wakeup must be fast too (the Algorithm-4.2 dispatch path).
    #[test]
    fn idle_pool_pinned_job_starts_fast() {
        let pool = ThreadPool::new(2);
        assert_idle_start_fast(&pool, |p, tx| {
            p.execute_on(0, move || {
                let _ = tx.send(Instant::now());
            });
        });
    }

    /// A panicking plain job must neither kill its worker nor leak
    /// `inflight` — `wait_idle` (and pool drop) must still return and the
    /// pool must keep executing later jobs.
    #[test]
    fn panicking_plain_job_does_not_wedge_pool() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("plain job exploded"));
        pool.wait_idle(); // must not hang
        let counter = Arc::new(AtomicU64::new(0));
        for i in 0..4 {
            let c = Arc::clone(&counter);
            pool.execute_on(i % 2, move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 4, "workers died after a job panic");
    }

    #[test]
    fn worker_arenas_persist_across_tasks() {
        let pool = ThreadPool::new(2);
        let a0 = Arc::clone(pool.arena(0));
        pool.execute_on(0, move || {
            let mut g = a0.lock().unwrap();
            ScratchArena::grow(&mut g.cols, 1024).fill(7.0);
        });
        pool.wait_idle();
        let g = pool.arena(0).lock().unwrap();
        assert!(g.cols.len() >= 1024, "arena did not persist");
        assert_eq!(g.cols[1023], 7.0);
    }

    /// The dispatch probe reports a sane overhead and the cached accessor
    /// is stable across calls.
    #[test]
    fn dispatch_probe_measures_and_caches() {
        let pool = ThreadPool::new(2);
        let probed = pool.probe_dispatch_overhead();
        assert!(probed > 0.0, "non-positive dispatch overhead");
        assert!(probed < 0.01, "implausible {probed}s per trivial job");
        let a = pool.dispatch_overhead_s();
        let b = pool.dispatch_overhead_s();
        assert_eq!(a, b, "cached measurement changed between calls");
        // The pool is still fully usable after probing.
        let counter = Arc::new(AtomicU64::new(0));
        for i in 0..8 {
            let c = Arc::clone(&counter);
            pool.execute_on(i % 2, move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn arena_grow_semantics() {
        let mut v = vec![3.0f32; 4];
        // grow never shrinks and keeps contents …
        assert_eq!(ScratchArena::grow(&mut v, 2), &[3.0, 3.0]);
        assert_eq!(v.len(), 4);
        // … grows with zeros past the old length …
        assert_eq!(ScratchArena::grow(&mut v, 6)[4..], [0.0, 0.0]);
        // … and grow_zeroed clears the requested prefix.
        assert_eq!(ScratchArena::grow_zeroed(&mut v, 4), &[0.0; 4]);
    }

    #[test]
    fn borrowed_jobs_run_before_barrier() {
        let pool = ThreadPool::new(2);
        let data = vec![1u64, 2, 3, 4];
        let sum = AtomicU64::new(0);
        {
            let d: &[u64] = &data;
            let s = &sum;
            for (i, _) in d.iter().enumerate() {
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    s.fetch_add(d[i], Ordering::SeqCst);
                });
                // SAFETY: wait_idle below outlives every borrow.
                unsafe { pool.execute_on_borrowed(i % 2, job) };
            }
            pool.wait_idle();
        }
        assert_eq!(sum.load(Ordering::SeqCst), 10);
    }
}
