//! Fixed-size worker thread pool — the substrate for the paper's inner-layer
//! multi-threaded parallelism (§4.2).
//!
//! Two usage modes:
//! * [`ThreadPool::execute`] — fire-and-forget jobs on a shared queue
//!   (classic work queue; used by generic parallel helpers).
//! * [`ThreadPool::execute_on`] — pin a job to a *specific* worker. The
//!   paper's Algorithm 4.2 assigns each task to the thread with minimal
//!   workload, which requires per-thread queues; the inner-layer scheduler
//!   builds on this mode.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    /// Jobs currently queued or running, for `wait_idle`.
    inflight: AtomicUsize,
    idle: Mutex<()>,
    idle_cv: Condvar,
}

/// A pool of worker threads with one queue per worker plus a shared queue.
pub struct ThreadPool {
    workers: Vec<Worker>,
    shared_tx: Sender<Job>,
    shared: Arc<Shared>,
}

struct Worker {
    tx: Sender<Job>,
    handle: Option<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (`n >= 1`).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "thread pool needs at least one worker");
        let shared = Arc::new(Shared {
            inflight: AtomicUsize::new(0),
            idle: Mutex::new(()),
            idle_cv: Condvar::new(),
        });
        // Shared queue: a dispatcher thread forwards to per-worker queues
        // round-robin would add latency; instead every worker also polls the
        // shared receiver behind a mutex.
        let (shared_tx, shared_rx) = channel::<Job>();
        let shared_rx = Arc::new(Mutex::new(shared_rx));
        let workers = (0..n)
            .map(|_| {
                let (tx, rx) = channel::<Job>();
                let shared_rx = Arc::clone(&shared_rx);
                let shared2 = Arc::clone(&shared);
                let handle = std::thread::spawn(move || worker_loop(rx, shared_rx, shared2));
                Worker { tx, handle: Some(handle) }
            })
            .collect();
        Self { workers, shared_tx, shared }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Queue a job on the shared queue (any worker picks it up).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.shared.inflight.fetch_add(1, Ordering::SeqCst);
        self.shared_tx.send(Box::new(job)).expect("pool closed");
    }

    /// Queue a job on worker `i`'s private queue (Algorithm 4.2 assignment).
    pub fn execute_on<F: FnOnce() + Send + 'static>(&self, i: usize, job: F) {
        self.shared.inflight.fetch_add(1, Ordering::SeqCst);
        self.workers[i].tx.send(Box::new(job)).expect("pool closed");
    }

    /// Block until every queued job has finished.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.idle.lock().unwrap();
        while self.shared.inflight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.idle_cv.wait(guard).unwrap();
        }
        drop(guard);
    }
}

fn worker_loop(rx: Receiver<Job>, shared_rx: Arc<Mutex<Receiver<Job>>>, shared: Arc<Shared>) {
    loop {
        // Private queue first (pinned tasks), then the shared queue.
        let job = match rx.try_recv() {
            Ok(job) => Some(job),
            Err(std::sync::mpsc::TryRecvError::Disconnected) => return,
            Err(std::sync::mpsc::TryRecvError::Empty) => {
                let job = {
                    let guard = shared_rx.lock().unwrap();
                    guard.try_recv().ok()
                };
                match job {
                    Some(j) => Some(j),
                    // Nothing anywhere: block briefly on the private queue so
                    // shutdown (sender drop) is still observed.
                    None => match rx.recv_timeout(std::time::Duration::from_millis(1)) {
                        Ok(j) => Some(j),
                        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
                        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
                    },
                }
            }
        };
        if let Some(job) = job {
            job();
            if shared.inflight.fetch_sub(1, Ordering::SeqCst) == 1 {
                let _guard = shared.idle.lock().unwrap();
                shared.idle_cv.notify_all();
            }
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.wait_idle();
        // Close all queues; workers exit on Disconnected.
        for w in &mut self.workers {
            // Replace sender with a dummy closed channel by dropping.
            let (dummy_tx, _) = channel();
            let old = std::mem::replace(&mut w.tx, dummy_tx);
            drop(old);
        }
        let (dummy_tx, _) = channel();
        drop(std::mem::replace(&mut self.shared_tx, dummy_tx));
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

/// Run `f(i)` for `i in 0..n` across the pool and collect results in order.
pub fn parallel_map<T: Send + 'static, F>(pool: &ThreadPool, n: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let results: Arc<Mutex<Vec<Option<T>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));
    for i in 0..n {
        let f = Arc::clone(&f);
        let results = Arc::clone(&results);
        pool.execute(move || {
            let v = f(i);
            results.lock().unwrap()[i] = Some(v);
        });
    }
    pool.wait_idle();
    Arc::try_unwrap(results)
        .unwrap_or_else(|_| panic!("outstanding references"))
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("job did not run"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn execute_on_pins_to_worker() {
        let pool = ThreadPool::new(3);
        let ids: Arc<Mutex<Vec<std::thread::ThreadId>>> = Arc::new(Mutex::new(vec![]));
        for _ in 0..20 {
            let ids = Arc::clone(&ids);
            pool.execute_on(1, move || {
                ids.lock().unwrap().push(std::thread::current().id());
            });
        }
        pool.wait_idle();
        let ids = ids.lock().unwrap();
        assert_eq!(ids.len(), 20);
        assert!(ids.iter().all(|&id| id == ids[0]), "pinned jobs ran on several threads");
    }

    #[test]
    fn parallel_map_ordered() {
        let pool = ThreadPool::new(4);
        let out = parallel_map(&pool, 50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle(); // must not hang
    }

    #[test]
    fn drop_joins_workers() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop waits
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
