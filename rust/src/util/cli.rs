//! Declarative command-line flag parser (clap is not available offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, defaults,
//! required flags, and auto-generated `--help` text. Used by `main.rs`,
//! the examples and the bench binaries.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone)]
struct FlagSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_bool: bool,
}

/// Builder + parser for one command's flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    program: String,
    about: String,
    specs: Vec<FlagSpec>,
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    MissingRequired(String),
    Invalid(String, String),
    HelpRequested,
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(name) => write!(f, "unknown flag --{name}"),
            CliError::MissingValue(name) => write!(f, "flag --{name} requires a value"),
            CliError::MissingRequired(name) => write!(f, "missing required flag --{name}"),
            CliError::Invalid(name, value) => write!(f, "invalid value for --{name}: {value}"),
            CliError::HelpRequested => write!(f, "help requested"),
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Self {
            program: program.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// Declare a value flag with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.specs.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_bool: false,
        });
        self
    }

    /// Declare a required value flag.
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.specs.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_bool: false,
        });
        self
    }

    /// Declare a boolean flag (false unless present).
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.specs.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some("false".to_string()),
            is_bool: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.program, self.about);
        let _ = writeln!(s, "\nFlags:");
        for spec in &self.specs {
            let default = match (&spec.default, spec.is_bool) {
                (_, true) => String::new(),
                (Some(d), false) => format!(" (default: {d})"),
                (None, false) => " (required)".to_string(),
            };
            let _ = writeln!(s, "  --{:<18} {}{}", spec.name, spec.help, default);
        }
        s
    }

    /// Parse the given argv tail (without the program name).
    pub fn parse(mut self, argv: &[String]) -> Result<Parsed, CliError> {
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if arg == "--help" || arg == "-h" {
                return Err(CliError::HelpRequested);
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| CliError::Unknown(name.clone()))?
                    .clone();
                let value = if spec.is_bool {
                    inline.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline {
                    v
                } else {
                    i += 1;
                    argv.get(i)
                        .cloned()
                        .ok_or_else(|| CliError::MissingValue(name.clone()))?
                };
                self.values.insert(name, value);
            } else {
                self.positional.push(arg.clone());
            }
            i += 1;
        }
        // Fill defaults, check required.
        for spec in &self.specs {
            if !self.values.contains_key(&spec.name) {
                match &spec.default {
                    Some(d) => {
                        self.values.insert(spec.name.clone(), d.clone());
                    }
                    None => return Err(CliError::MissingRequired(spec.name.clone())),
                }
            }
        }
        Ok(Parsed { values: self.values, positional: self.positional })
    }
}

/// Parsed flag values with typed accessors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn str(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} was not declared"))
    }

    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        self.str(name)
            .parse()
            .map_err(|_| CliError::Invalid(name.to_string(), self.str(name).to_string()))
    }

    pub fn u64(&self, name: &str) -> Result<u64, CliError> {
        self.str(name)
            .parse()
            .map_err(|_| CliError::Invalid(name.to_string(), self.str(name).to_string()))
    }

    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        self.str(name)
            .parse()
            .map_err(|_| CliError::Invalid(name.to_string(), self.str(name).to_string()))
    }

    pub fn bool(&self, name: &str) -> bool {
        self.str(name) == "true"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    fn sample() -> Args {
        Args::new("test", "a test command")
            .opt("nodes", "8", "number of nodes")
            .opt("strategy", "agwu", "update strategy")
            .flag("verbose", "log more")
            .req("out", "output path")
    }

    #[test]
    fn defaults_and_required() {
        let p = sample().parse(&argv(&["--out", "x.json"])).unwrap();
        assert_eq!(p.usize("nodes").unwrap(), 8);
        assert_eq!(p.str("strategy"), "agwu");
        assert!(!p.bool("verbose"));
        assert_eq!(p.str("out"), "x.json");
    }

    #[test]
    fn explicit_values_and_equals_syntax() {
        let p = sample()
            .parse(&argv(&["--nodes=32", "--verbose", "--out=o", "--strategy", "sgwu"]))
            .unwrap();
        assert_eq!(p.usize("nodes").unwrap(), 32);
        assert!(p.bool("verbose"));
        assert_eq!(p.str("strategy"), "sgwu");
    }

    #[test]
    fn missing_required_rejected() {
        assert_eq!(
            sample().parse(&argv(&["--nodes", "4"])),
            Err(CliError::MissingRequired("out".into()))
        );
    }

    #[test]
    fn unknown_flag_rejected() {
        assert_eq!(
            sample().parse(&argv(&["--out", "x", "--bogus", "1"])),
            Err(CliError::Unknown("bogus".into()))
        );
    }

    #[test]
    fn missing_value_rejected() {
        assert_eq!(
            sample().parse(&argv(&["--out"])),
            Err(CliError::MissingValue("out".into()))
        );
    }

    #[test]
    fn positional_collected() {
        let p = sample().parse(&argv(&["run", "--out", "x", "fast"])).unwrap();
        assert_eq!(p.positional, vec!["run".to_string(), "fast".to_string()]);
    }

    #[test]
    fn help_requested() {
        assert_eq!(sample().parse(&argv(&["-h"])), Err(CliError::HelpRequested));
        assert!(sample().usage().contains("--nodes"));
    }

    #[test]
    fn invalid_numeric() {
        let p = sample().parse(&argv(&["--nodes", "abc", "--out", "x"])).unwrap();
        assert!(p.usize("nodes").is_err());
    }
}
