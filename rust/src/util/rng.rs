//! Deterministic pseudo-random number generation.
//!
//! The offline crate registry has no `rand` crate, so the repository carries
//! its own generators: [`SplitMix64`] for seeding and [`Xoshiro256`]
//! (xoshiro256**) as the workhorse. Both are tiny, fast, and give the
//! reproducible streams the experiments depend on (every experiment id is
//! keyed by an explicit seed recorded in EXPERIMENTS.md).

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the main PRNG used across data generation, simulation
/// jitter, and property tests.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64, per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream (used to give each simulated node /
    /// worker its own generator).
    pub fn fork(&mut self, stream: u64) -> Self {
        Self::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, bound)` (Lemire-style rejection-free enough
    /// for our bounds; uses 64-bit multiply-shift).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller.
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with the given mean and standard deviation.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.next_normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut target = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values for seed 0 from the SplitMix64 reference code.
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(a, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_per_seed() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Xoshiro256::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Xoshiro256::new(1);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut rng = Xoshiro256::new(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_in_bounds() {
        let mut rng = Xoshiro256::new(3);
        for bound in [1u64, 2, 7, 1000] {
            for _ in 0..1000 {
                assert!(rng.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256::new(4);
        let n = 100_000;
        let samples: Vec<f64> = (0..n).map(|_| rng.next_normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut rng = Xoshiro256::new(6);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.weighted_index(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac2 = counts[2] as f64 / 30_000.0;
        assert!((frac2 - 0.7).abs() < 0.03, "frac2={frac2}");
    }

    #[test]
    fn fork_streams_diverge() {
        let mut root = Xoshiro256::new(7);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
