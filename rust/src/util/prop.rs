//! Mini property-testing helper (proptest is not available offline).
//!
//! [`check`] runs a property over `n` random cases drawn from a seeded
//! generator; on failure it reports the case index, the seed to reproduce,
//! and the failure message. Shrinking is approximated by re-running the
//! failing case with "smaller" generator bounds where the caller opts in via
//! [`Gen::sized`].
//!
//! ```ignore
//! prop::check("partition sums", 200, |g| {
//!     let n = g.usize(1, 10_000);
//!     let parts = partition(n, g.usize(1, 16));
//!     prop::assert_eq_msg(parts.iter().sum::<usize>(), n, "must conserve")
//! });
//! ```

use super::rng::Xoshiro256;

/// Random-case generator handed to each property invocation.
pub struct Gen {
    rng: Xoshiro256,
    /// Scale factor in (0, 1]; early cases are generated small to surface
    /// minimal counterexamples first (poor man's shrinking).
    size: f64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Self {
        Self { rng: Xoshiro256::new(seed), size }
    }

    /// Uniform usize in `[lo, hi]` (inclusive), scaled by the case size.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = hi - lo;
        let scaled = ((span as f64) * self.size).ceil() as usize;
        lo + self.rng.next_below(scaled as u64 + 1) as usize
    }

    /// Uniform usize in `[lo, hi]` ignoring the size scale.
    pub fn usize_full(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.next_below((hi - lo) as u64 + 1) as usize
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.rng.next_below(hi - lo + 1)
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        self.rng.normal(mean, std)
    }

    /// Vector of f64 drawn uniformly from `[lo, hi)`.
    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64(lo, hi)).collect()
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f64(lo as f64, hi as f64) as f32).collect()
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.next_below(items.len() as u64) as usize]
    }

    pub fn rng(&mut self) -> &mut Xoshiro256 {
        &mut self.rng
    }
}

/// Outcome of a single property case.
pub type PropResult = Result<(), String>;

/// Run `property` over `cases` random cases. Panics with a reproducible
/// report on the first failure.
pub fn check<F: FnMut(&mut Gen) -> PropResult>(name: &str, cases: u32, mut property: F) {
    let base_seed = env_seed().unwrap_or(0xBF7C_11D5);
    for case in 0..cases {
        // Grow case size from 10% to 100% over the run.
        let size = 0.1 + 0.9 * (case as f64 / cases.max(1) as f64);
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut gen = Gen::new(seed, size);
        if let Err(msg) = property(&mut gen) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (reproduce with PROP_SEED={base_seed}): {msg}"
            );
        }
    }
}

fn env_seed() -> Option<u64> {
    std::env::var("PROP_SEED").ok()?.parse().ok()
}

/// Assertion helpers returning `PropResult` so properties read cleanly.
pub fn assert_true(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

pub fn assert_eq_msg<T: PartialEq + std::fmt::Debug>(a: T, b: T, msg: &str) -> PropResult {
    if a == b {
        Ok(())
    } else {
        Err(format!("{msg}: {a:?} != {b:?}"))
    }
}

pub fn assert_close(a: f64, b: f64, tol: f64, msg: &str) -> PropResult {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{msg}: {a} !≈ {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always true", 50, |g| {
            count += 1;
            let x = g.usize(0, 100);
            assert_true(x <= 100, "in range")
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always false' failed")]
    fn failing_property_panics_with_report() {
        check("always false", 10, |_| Err("nope".to_string()));
    }

    #[test]
    fn sizes_grow_over_run() {
        let mut maxima = Vec::new();
        check("observe sizes", 100, |g| {
            maxima.push(g.usize(0, 1000));
            Ok(())
        });
        let early_max = *maxima[..20].iter().max().unwrap();
        let late_max = *maxima[80..].iter().max().unwrap();
        assert!(late_max > early_max, "late {late_max} vs early {early_max}");
    }

    #[test]
    fn assert_close_relative() {
        assert!(assert_close(1e9, 1e9 + 1.0, 1e-6, "big").is_ok());
        assert!(assert_close(1.0, 1.1, 1e-6, "off").is_err());
    }

    #[test]
    fn gen_bounds_respected() {
        check("bounds", 100, |g| {
            let x = g.usize(5, 10);
            assert_true((5..=10).contains(&x), "usize bounds")?;
            let y = g.f64(-1.0, 1.0);
            assert_true((-1.0..1.0).contains(&y), "f64 bounds")?;
            let z = g.u64(3, 4);
            assert_true((3..=4).contains(&z), "u64 bounds")
        });
    }
}
