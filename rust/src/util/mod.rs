//! Substrate utilities built from scratch for the offline environment:
//! PRNG, JSON, statistics, CLI parsing, thread pool, bench harness, and a
//! mini property-testing framework.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod signal;
pub mod stats;
pub mod threadpool;
