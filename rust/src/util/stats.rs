//! Descriptive statistics used by metrics, benches and the simulator:
//! mean/std/percentiles, trapezoidal AUC (Fig. 11b), and the workload
//! balance index reported in Fig. 15(b).

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation on the sorted copy; `p` in `[0, 100]`.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Trapezoidal area under the curve `(x, y)`; used for the paper's AUC
/// comparison (Fig. 11b). Points must be sorted by `x`.
pub fn auc(points: &[(f64, f64)]) -> f64 {
    points
        .windows(2)
        .map(|w| (w[1].0 - w[0].0) * (w[0].1 + w[1].1) / 2.0)
        .sum()
}

/// Workload balance index in `(0, 1]` — the paper reports BPT-CNN keeping it
/// between 0.80 and 0.89 (Fig. 15b). Defined as mean(load) / max(load):
/// 1.0 = perfectly balanced, → 0 when one node dominates.
pub fn balance_index(loads: &[f64]) -> f64 {
    if loads.is_empty() {
        return 1.0;
    }
    let mx = max(loads);
    if mx <= 0.0 {
        return 1.0;
    }
    mean(loads) / mx
}

/// Online mean/variance accumulator (Welford) for streaming bench samples.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_slices() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(balance_index(&[]), 1.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
        // interpolated
        let xs2 = [0.0, 10.0];
        assert_eq!(percentile(&xs2, 75.0), 7.5);
    }

    #[test]
    fn auc_of_unit_square() {
        let pts = [(0.0, 1.0), (1.0, 1.0)];
        assert!((auc(&pts) - 1.0).abs() < 1e-12);
        let tri = [(0.0, 0.0), (1.0, 1.0)];
        assert!((auc(&tri) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn balance_index_bounds() {
        assert_eq!(balance_index(&[5.0, 5.0, 5.0]), 1.0);
        let idx = balance_index(&[1.0, 1.0, 8.0]);
        assert!(idx > 0.0 && idx < 0.5);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.5, -3.0, 4.0, 0.5];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std_dev() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!(w.count(), 5);
    }
}
