//! Minimal JSON parser + serializer.
//!
//! `serde`/`serde_json` are not in the offline registry, so configs, artifact
//! manifests (`artifacts/*/meta.json`) and metric dumps go through this
//! hand-rolled implementation. It supports the full JSON grammar with the
//! usual Rust conveniences and precise error positions.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a `BTreeMap` so serialization is
/// deterministic (stable diffs for metric dumps).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access; returns `Json::Null` when missing.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(v) => v.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(values: &[f64]) -> Json {
        Json::Arr(values.iter().map(|v| Json::Num(*v)).collect())
    }

    pub fn arr_str(values: &[&str]) -> Json {
        Json::Arr(values.iter().map(|v| Json::Str(v.to_string())).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    self.pos = (start + len).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_value(self, &mut s);
        f.write_str(&s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").idx(0).as_f64(), Some(1.0));
        assert_eq!(v.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn error_positions() {
        let err = Json::parse("[1, x]").unwrap_err();
        assert_eq!(err.pos, 4);
    }

    #[test]
    fn roundtrip_display_parse() {
        let v = Json::obj(vec![
            ("nums", Json::arr_f64(&[1.0, 2.5, -3.0])),
            ("name", Json::from("bpt\"cnn\n")),
            ("flag", Json::from(true)),
            ("nested", Json::obj(vec![("x", Json::Null)])),
        ]);
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn integers_serialized_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
          "config": {"name": "e2e", "batch_size": 32},
          "params": [{"name": "conv0.filter", "shape": [3,3,1,8]}],
          "param_count": 38306
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("config").get("batch_size").as_usize(), Some(32));
        let p0 = v.get("params").idx(0);
        assert_eq!(p0.get("name").as_str(), Some("conv0.filter"));
        let shape: Vec<usize> = p0
            .get("shape")
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![3, 3, 1, 8]);
    }
}
