//! Micro-benchmark harness (criterion is not available offline).
//!
//! Usage in a `harness = false` bench target:
//! ```ignore
//! let mut b = Bench::from_env("bench_inner");
//! b.bench("conv_tasks/seq", || { run_sequential(); });
//! b.bench_with_throughput("ps_update/agwu", weight_bytes as f64, || { ... });
//! b.finish();
//! ```
//! Each benchmark is warmed up, then timed for a fixed wall-clock budget;
//! mean / p50 / p95 / std-dev and optional throughput are printed in aligned
//! rows so `cargo bench | tee` output is directly pasteable into
//! EXPERIMENTS.md.

use std::time::{Duration, Instant};

use super::stats;

/// One benchmark's summary statistics (nanoseconds per iteration).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub std_ns: f64,
    /// Optional bytes (or items) processed per iteration, for throughput.
    pub units_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn throughput_per_sec(&self) -> Option<f64> {
        self.units_per_iter.map(|u| u / (self.mean_ns / 1e9))
    }
}

/// Harness configuration. `QUICK_BENCH=1` in the environment shrinks the
/// measurement budget (used by `cargo test`-adjacent smoke runs).
pub struct Bench {
    suite: String,
    warmup: Duration,
    budget: Duration,
    max_iters: u64,
    results: Vec<BenchResult>,
}

impl Bench {
    pub fn new(suite: &str, warmup: Duration, budget: Duration) -> Self {
        Self {
            suite: suite.to_string(),
            warmup,
            budget,
            max_iters: 1_000_000,
            results: Vec::new(),
        }
    }

    /// Standard settings: 0.2 s warmup, 1 s measurement (0.05/0.2 s when
    /// `QUICK_BENCH=1`).
    pub fn from_env(suite: &str) -> Self {
        let quick = std::env::var("QUICK_BENCH").map(|v| v == "1").unwrap_or(false);
        if quick {
            Self::new(suite, Duration::from_millis(50), Duration::from_millis(200))
        } else {
            Self::new(suite, Duration::from_millis(200), Duration::from_secs(1))
        }
    }

    /// Time `f` and record the result under `name`.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> &BenchResult {
        self.bench_inner(name, None, f)
    }

    /// Time `f`, additionally reporting `units / s` throughput (units =
    /// bytes, samples, events … processed per call).
    pub fn bench_with_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        units_per_iter: f64,
        f: F,
    ) -> &BenchResult {
        self.bench_inner(name, Some(units_per_iter), f)
    }

    fn bench_inner<F: FnMut()>(
        &mut self,
        name: &str,
        units: Option<f64>,
        mut f: F,
    ) -> &BenchResult {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            f();
        }
        // Measure individual iterations until the budget is exhausted.
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.budget && (samples_ns.len() as u64) < self.max_iters {
            let t0 = Instant::now();
            f();
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        let result = BenchResult {
            name: format!("{}/{}", self.suite, name),
            iters: samples_ns.len() as u64,
            mean_ns: stats::mean(&samples_ns),
            p50_ns: stats::percentile(&samples_ns, 50.0),
            p95_ns: stats::percentile(&samples_ns, 95.0),
            std_ns: stats::std_dev(&samples_ns),
            units_per_iter: units,
        };
        println!("{}", format_row(&result));
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Print the footer; returns all results for programmatic use.
    pub fn finish(self) -> Vec<BenchResult> {
        println!(
            "[{}] {} benchmark(s) complete",
            self.suite,
            self.results.len()
        );
        self.results
    }
}

/// Render nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:7.1} ns")
    } else if ns < 1e6 {
        format!("{:7.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:7.2} ms", ns / 1e6)
    } else {
        format!("{:7.2} s ", ns / 1e9)
    }
}

fn fmt_rate(per_sec: f64) -> String {
    if per_sec >= 1e9 {
        format!("{:6.2} G/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:6.2} M/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:6.2} K/s", per_sec / 1e3)
    } else {
        format!("{per_sec:6.1} /s")
    }
}

fn format_row(r: &BenchResult) -> String {
    let mut row = format!(
        "{:<44} {:>8} iters  mean {}  p50 {}  p95 {}  ±{}",
        r.name,
        r.iters,
        fmt_ns(r.mean_ns),
        fmt_ns(r.p50_ns),
        fmt_ns(r.p95_ns),
        fmt_ns(r.std_ns),
    );
    if let Some(rate) = r.throughput_per_sec() {
        row.push_str(&format!("  {}", fmt_rate(rate)));
    }
    row
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> Bench {
        Bench::new("test", Duration::from_millis(1), Duration::from_millis(10))
    }

    #[test]
    fn records_iterations() {
        let mut b = quick();
        let r = b.bench("noop", || {}).clone();
        assert!(r.iters > 10);
        assert!(r.mean_ns >= 0.0);
        let all = b.finish();
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].name, "test/noop");
    }

    #[test]
    fn throughput_computed() {
        let mut b = quick();
        let r = b.bench_with_throughput("bytes", 1024.0, || {
            std::hint::black_box([0u8; 64]);
        });
        let rate = r.throughput_per_sec().unwrap();
        assert!(rate > 0.0);
    }

    #[test]
    fn slower_function_measures_slower() {
        let mut b = quick();
        let fast = b
            .bench("fast", || {
                std::hint::black_box(1 + 1);
            })
            .mean_ns;
        let slow = b
            .bench("slow", || {
                let mut acc = 0u64;
                for i in 0..20_000u64 {
                    acc = acc.wrapping_add(std::hint::black_box(i));
                }
                std::hint::black_box(acc);
            })
            .mean_ns;
        assert!(slow > fast * 5.0, "slow={slow} fast={fast}");
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).contains("s"));
    }
}
