//! Terminal line charts — the paper's figures, rendered as ASCII so
//! `bptcnn experiment figNN` output is self-contained.

/// Render one or more named series as an ASCII chart. Points are (x, y);
/// series are marked with distinct glyphs.
pub fn ascii_chart(title: &str, series: &[(&str, Vec<(f64, f64)>)], width: usize, height: usize) -> String {
    const GLYPHS: [char; 6] = ['*', 'o', '+', 'x', '#', '@'];
    let all: Vec<(f64, f64)> = series.iter().flat_map(|(_, pts)| pts.iter().copied()).collect();
    if all.is_empty() {
        return format!("{title}: (no data)\n");
    }
    let (mut xmin, mut xmax, mut ymin, mut ymax) =
        (f64::INFINITY, f64::NEG_INFINITY, f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &all {
        xmin = xmin.min(x);
        xmax = xmax.max(x);
        ymin = ymin.min(y);
        ymax = ymax.max(y);
    }
    if (xmax - xmin).abs() < 1e-12 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, pts)) in series.iter().enumerate() {
        let glyph = GLYPHS[si % GLYPHS.len()];
        for &(x, y) in pts {
            let cx = (((x - xmin) / (xmax - xmin)) * (width - 1) as f64).round() as usize;
            let cy = (((y - ymin) / (ymax - ymin)) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx] = glyph;
        }
    }
    let mut out = String::new();
    out.push_str(&format!("{title}\n"));
    out.push_str(&format!("{ymax:>10.3} ┤"));
    out.push_str(&grid[0].iter().collect::<String>());
    out.push('\n');
    for row in grid.iter().take(height - 1).skip(1) {
        out.push_str("           │");
        out.push_str(&row.iter().collect::<String>());
        out.push('\n');
    }
    out.push_str(&format!("{ymin:>10.3} ┤"));
    out.push_str(&grid[height - 1].iter().collect::<String>());
    out.push('\n');
    out.push_str(&format!(
        "           └{}\n            {:<10.3}{:>width$.3}\n",
        "─".repeat(width),
        xmin,
        xmax,
        width = width - 10
    ));
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (name, _))| format!("{} {}", GLYPHS[i % GLYPHS.len()], name))
        .collect();
    out.push_str(&format!("            {}\n", legend.join("   ")));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_series() {
        let s = ascii_chart(
            "test",
            &[
                ("up", vec![(0.0, 0.0), (1.0, 1.0)]),
                ("down", vec![(0.0, 1.0), (1.0, 0.0)]),
            ],
            40,
            10,
        );
        assert!(s.contains("test"));
        assert!(s.contains('*') && s.contains('o'));
        assert!(s.contains("up") && s.contains("down"));
    }

    #[test]
    fn empty_series_safe() {
        let s = ascii_chart("empty", &[("none", vec![])], 40, 10);
        assert!(s.contains("no data"));
    }

    #[test]
    fn constant_series_safe() {
        let s = ascii_chart("flat", &[("c", vec![(1.0, 5.0), (2.0, 5.0)])], 30, 8);
        assert!(s.contains('*'));
    }
}
