//! Result presentation: aligned tables (the paper's rows), ASCII charts
//! (the paper's figures in terminal form), and JSON run logs.

pub mod chart;
pub mod table;

pub use chart::ascii_chart;
pub use table::Table;

use crate::util::json::Json;

/// Append a run record to a JSON-lines log file (used by the experiment
/// harness so EXPERIMENTS.md numbers are reproducible from disk).
pub fn log_run(path: &str, record: Json) -> std::io::Result<()> {
    use std::io::Write;
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    writeln!(f, "{record}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_run_appends_parseable_lines() {
        let path = std::env::temp_dir().join(format!("bptcnn_log_{}.jsonl", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        log_run(&path, Json::obj(vec![("a", Json::from(1.0))])).unwrap();
        log_run(&path, Json::obj(vec![("a", Json::from(2.0))])).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(Json::parse(lines[1]).unwrap().get("a").as_f64(), Some(2.0));
        let _ = std::fs::remove_file(&path);
    }
}
