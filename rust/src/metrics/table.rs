//! Aligned text tables for the experiment harness output — each paper table
//! and figure regenerator prints one of these.

/// A simple column-aligned table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| format!("{c}")).collect();
        self.row(&cells)
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with column alignment (first column left, rest right).
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("\n## {}\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("| ");
            for (i, cell) in cells.iter().enumerate() {
                if i == 0 {
                    line.push_str(&format!("{:<width$}", cell, width = widths[i]));
                } else {
                    line.push_str(&format!("{:>width$}", cell, width = widths[i]));
                }
                line.push_str(" | ");
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        let sep: Vec<String> = (0..ncols).map(|i| "-".repeat(widths[i])).collect();
        out.push_str(&fmt_row(&sep));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds adaptively.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2}s")
    } else {
        format!("{:.1}min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["algo", "time"]);
        t.row(&["bpt-cnn".into(), "62.77".into()]);
        t.row(&["tensorflow-like".into(), "54.38".into()]);
        let s = t.render();
        assert!(s.contains("## demo"));
        let lines: Vec<&str> = s.lines().filter(|l| l.starts_with('|')).collect();
        assert_eq!(lines.len(), 4); // header + sep + 2 rows
        // All rows equal width.
        assert_eq!(lines[0].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(0.00001).contains("µs"));
        assert!(fmt_secs(0.01).contains("ms"));
        assert!(fmt_secs(5.0).contains('s'));
        assert!(fmt_secs(300.0).contains("min"));
    }
}
