//! Outer-layer benchmarks: parameter-server update throughput (SGWU Eq. 7
//! vs AGWU Eq. 10) across the paper's Table-2 weight-set sizes, IDPA
//! scheduling cost, and weight-set algebra primitives.

use bptcnn::config::NetworkConfig;
use bptcnn::nn::Network;
use bptcnn::outer::{IdpaPartitioner, ParamServer};
use bptcnn::util::bench::Bench;

fn main() {
    let mut b = Bench::from_env("outer");

    for case in [1usize, 4, 7] {
        let cfg = NetworkConfig::table2_case(case);
        let bytes = cfg.weight_bytes() as f64;
        let init = Network::init(&cfg, 1).weights;
        let local = Network::init(&cfg, 2).weights;

        // SGWU round with m = 4 locals (Eq. 7).
        let locals: Vec<_> = (0..4).map(|_| (local.clone(), 0.8)).collect();
        let mut ps = ParamServer::new(init.clone(), 4);
        b.bench_with_throughput(&format!("sgwu/case{case}_{}KB", cfg.weight_bytes() / 1024), bytes, || {
            ps.update_sgwu(&locals);
        });

        // AGWU single submission (Eq. 10, incl. increment + γ).
        let mut ps = ParamServer::new(init.clone(), 4);
        let (_, base) = ps.fetch(0);
        b.bench_with_throughput(&format!("agwu/case{case}_{}KB", cfg.weight_bytes() / 1024), bytes, || {
            ps.update_agwu(0, &local, base.min(ps.version()), 0.8);
        });

        // Fetch: Arc snapshot (refcount bump) vs the legacy clone-per-fetch
        // the server used to pay (reconstructed as fetch + forced deep copy).
        let mut ps = ParamServer::new(init.clone(), 4);
        b.bench_with_throughput(&format!("fetch/case{case}_legacy_clone"), bytes, || {
            let (w, _) = ps.fetch(0);
            std::hint::black_box((*w).clone());
        });
        b.bench_with_throughput(&format!("fetch/case{case}_arc_snapshot"), bytes, || {
            std::hint::black_box(ps.fetch(0));
        });

        // Full fetch→train(elided)→submit cycle: legacy (worker owns a deep
        // copy of the fetched set) vs Arc snapshots end to end.
        let mut ps = ParamServer::new(init.clone(), 4);
        b.bench_with_throughput(&format!("agwu_cycle/case{case}_legacy"), 2.0 * bytes, || {
            let (w, k) = ps.fetch(0);
            let owned = (*w).clone();
            ps.update_agwu(0, &owned, k, 0.8);
        });
        let mut ps = ParamServer::new(init.clone(), 4);
        b.bench_with_throughput(&format!("agwu_cycle/case{case}_arc"), 2.0 * bytes, || {
            let (w, k) = ps.fetch(0);
            ps.update_agwu(0, &w, k, 0.8);
        });

        // Weight-set algebra hot path.
        let mut acc = init.clone();
        b.bench_with_throughput(&format!("weightset_axpy/case{case}"), bytes, || {
            acc.axpy(0.001, &local);
        });
    }

    // IDPA schedule construction at paper scale.
    b.bench("idpa/30nodes_10batches_600k", || {
        let freqs: Vec<f64> = (0..30).map(|j| 1.6 + 0.05 * j as f64).collect();
        let mut p = IdpaPartitioner::new(600_000, 10, &freqs);
        p.run_with_oracle(|j| 1.0 / (1.0 + j as f64));
    });

    b.finish();
}
