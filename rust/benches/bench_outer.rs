//! Outer-layer benchmarks: parameter-server update throughput (SGWU Eq. 7
//! vs AGWU Eq. 10) across the paper's Table-2 weight-set sizes, transport
//! backends (in-process vs loopback TCP, with an Eq. 11 measured-vs-modeled
//! line), IDPA scheduling cost, and weight-set algebra primitives.

use bptcnn::config::NetworkConfig;
use bptcnn::nn::Network;
use bptcnn::outer::{IdpaPartitioner, ParamServer};
use bptcnn::util::bench::Bench;

fn main() {
    let mut b = Bench::from_env("outer");

    for case in [1usize, 4, 7] {
        let cfg = NetworkConfig::table2_case(case);
        let bytes = cfg.weight_bytes() as f64;
        let init = Network::init(&cfg, 1).weights;
        let local = Network::init(&cfg, 2).weights;

        // SGWU round with m = 4 locals (Eq. 7).
        let locals: Vec<_> = (0..4).map(|_| (local.clone(), 0.8)).collect();
        let mut ps = ParamServer::new(init.clone(), 4);
        b.bench_with_throughput(&format!("sgwu/case{case}_{}KB", cfg.weight_bytes() / 1024), bytes, || {
            ps.update_sgwu(&locals);
        });

        // AGWU single submission (Eq. 10, incl. increment + γ).
        let mut ps = ParamServer::new(init.clone(), 4);
        let (_, base) = ps.fetch(0);
        b.bench_with_throughput(&format!("agwu/case{case}_{}KB", cfg.weight_bytes() / 1024), bytes, || {
            ps.update_agwu(0, &local, base.min(ps.version()), 0.8);
        });

        // Fetch: Arc snapshot (refcount bump) vs the legacy clone-per-fetch
        // the server used to pay (reconstructed as fetch + forced deep copy).
        let mut ps = ParamServer::new(init.clone(), 4);
        b.bench_with_throughput(&format!("fetch/case{case}_legacy_clone"), bytes, || {
            let (w, _) = ps.fetch(0);
            std::hint::black_box((*w).clone());
        });
        b.bench_with_throughput(&format!("fetch/case{case}_arc_snapshot"), bytes, || {
            std::hint::black_box(ps.fetch(0));
        });

        // Full fetch→train(elided)→submit cycle: legacy (worker owns a deep
        // copy of the fetched set) vs Arc snapshots end to end.
        let mut ps = ParamServer::new(init.clone(), 4);
        b.bench_with_throughput(&format!("agwu_cycle/case{case}_legacy"), 2.0 * bytes, || {
            let (w, k) = ps.fetch(0);
            let owned = (*w).clone();
            ps.update_agwu(0, &owned, k, 0.8);
        });
        let mut ps = ParamServer::new(init.clone(), 4);
        b.bench_with_throughput(&format!("agwu_cycle/case{case}_arc"), 2.0 * bytes, || {
            let (w, k) = ps.fetch(0);
            ps.update_agwu(0, &w, k, 0.8);
        });

        // Weight-set algebra hot path.
        let mut acc = init.clone();
        b.bench_with_throughput(&format!("weightset_axpy/case{case}"), bytes, || {
            acc.axpy(0.001, &local);
        });
    }

    // Transport-level cost of one weight-set move — the real Eq. 11 c_w —
    // for the in-process backend (Arc bump + by-value submit) vs real
    // loopback sockets (frame encode → kernel → decode), plus a printed
    // measured-vs-modeled Eq. 11 comparison line.
    {
        use bptcnn::config::UpdateStrategy;
        use bptcnn::outer::{
            serve, InProcTransport, ServeOptions, SubmitMeta, SubmitMode, TcpTransport,
            TransferModel, Transport,
        };
        use std::sync::{Arc, Mutex};

        let cfg = NetworkConfig::table2_case(1);
        let bytes = cfg.weight_bytes() as f64;
        let init = Network::init(&cfg, 1).weights;

        let ps = Arc::new(Mutex::new(ParamServer::new(init.clone(), 1)));
        let mut t = InProcTransport::new(Arc::clone(&ps), 0);
        b.bench_with_throughput("transport/inproc_fetch", bytes, || {
            std::hint::black_box(t.fetch_global().unwrap());
        });
        b.bench_with_throughput("transport/inproc_cycle", 2.0 * bytes, || {
            let (w, base) = t.fetch_global().unwrap();
            let local = (*w).clone();
            let meta = SubmitMeta {
                mode: SubmitMode::Agwu,
                base,
                accuracy: 0.8,
                loss: 0.5,
                want_snapshot: false,
            };
            t.submit(local, &meta).unwrap();
        });
        drop(t);
        drop(ps);

        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let opts = ServeOptions { nodes: 1, update: UpdateStrategy::Agwu, verbose: false };
        let server = {
            let init = init.clone();
            std::thread::spawn(move || serve(listener, init, opts))
        };
        let mut t = TcpTransport::connect(&addr, 0).unwrap();
        b.bench_with_throughput("transport/tcp_loopback_fetch", bytes, || {
            std::hint::black_box(t.fetch_global().unwrap());
        });
        b.bench_with_throughput("transport/tcp_loopback_cycle", 2.0 * bytes, || {
            let (w, base) = t.fetch_global().unwrap();
            let local = (*w).clone();
            let meta = SubmitMeta {
                mode: SubmitMode::Agwu,
                base,
                accuracy: 0.8,
                loss: 0.5,
                want_snapshot: false,
            };
            t.submit(local, &meta).unwrap();
        });
        let st = t.stats();
        t.finish().unwrap();
        let report = server.join().unwrap().unwrap();

        // Eq. 11 comparison: measured loopback round (fetch + submit = the
        // 2·c_w of one node-iteration) vs the TransferModel on nominal 1 GbE.
        let per_fetch = st.fetch_wall_s / st.fetches.max(1) as f64;
        let per_submit = st.submit_wall_s / st.submits.max(1) as f64;
        let model = TransferModel::new(117.0e6, 100e-6); // ~1 GbE effective
        let modeled = 2.0 * model.transfer_time(cfg.weight_bytes());
        println!(
            "eq11/case1: measured loopback 2·c_w = {:.3} ms (fetch {:.3} + submit {:.3}), \
             modeled 1 GbE = {:.3} ms, wire/logical bytes = {:.2}",
            (per_fetch + per_submit) * 1e3,
            per_fetch * 1e3,
            per_submit * 1e3,
            modeled * 1e3,
            report.comm.wire_bytes as f64 / report.comm.bytes.max(1) as f64,
        );
    }

    // IDPA schedule construction at paper scale.
    b.bench("idpa/30nodes_10batches_600k", || {
        let freqs: Vec<f64> = (0..30).map(|j| 1.6 + 0.05 * j as f64).collect();
        let mut p = IdpaPartitioner::new(600_000, 10, &freqs);
        p.run_with_oracle(|j| 1.0 / (1.0 + j as f64));
    });

    b.finish();
}
