//! Outer-layer benchmarks: parameter-server update throughput (SGWU Eq. 7
//! vs AGWU Eq. 10) across the paper's Table-2 weight-set sizes, transport
//! backends (in-process vs loopback TCP, with an Eq. 11 measured-vs-modeled
//! line), IDPA scheduling cost, and weight-set algebra primitives.

use bptcnn::config::NetworkConfig;
use bptcnn::nn::Network;
use bptcnn::outer::{IdpaPartitioner, ParamServer};
use bptcnn::util::bench::Bench;

fn main() {
    let mut b = Bench::from_env("outer");

    for case in [1usize, 4, 7] {
        let cfg = NetworkConfig::table2_case(case);
        let bytes = cfg.weight_bytes() as f64;
        let init = Network::init(&cfg, 1).weights;
        let local = Network::init(&cfg, 2).weights;

        // SGWU round with m = 4 locals (Eq. 7).
        let locals: Vec<_> = (0..4).map(|_| (local.clone(), 0.8)).collect();
        let mut ps = ParamServer::new(init.clone(), 4);
        b.bench_with_throughput(&format!("sgwu/case{case}_{}KB", cfg.weight_bytes() / 1024), bytes, || {
            ps.update_sgwu(&locals);
        });

        // AGWU single submission (Eq. 10, incl. increment + γ).
        let mut ps = ParamServer::new(init.clone(), 4);
        let (_, base) = ps.fetch(0);
        b.bench_with_throughput(&format!("agwu/case{case}_{}KB", cfg.weight_bytes() / 1024), bytes, || {
            ps.update_agwu(0, &local, base.min(ps.version()), 0.8);
        });

        // Fetch: Arc snapshot (refcount bump) vs the legacy clone-per-fetch
        // the server used to pay (reconstructed as fetch + forced deep copy).
        let mut ps = ParamServer::new(init.clone(), 4);
        b.bench_with_throughput(&format!("fetch/case{case}_legacy_clone"), bytes, || {
            let (w, _) = ps.fetch(0);
            std::hint::black_box((*w).clone());
        });
        b.bench_with_throughput(&format!("fetch/case{case}_arc_snapshot"), bytes, || {
            std::hint::black_box(ps.fetch(0));
        });

        // Full fetch→train(elided)→submit cycle: legacy (worker owns a deep
        // copy of the fetched set) vs Arc snapshots end to end.
        let mut ps = ParamServer::new(init.clone(), 4);
        b.bench_with_throughput(&format!("agwu_cycle/case{case}_legacy"), 2.0 * bytes, || {
            let (w, k) = ps.fetch(0);
            let owned = (*w).clone();
            ps.update_agwu(0, &owned, k, 0.8);
        });
        let mut ps = ParamServer::new(init.clone(), 4);
        b.bench_with_throughput(&format!("agwu_cycle/case{case}_arc"), 2.0 * bytes, || {
            let (w, k) = ps.fetch(0);
            ps.update_agwu(0, &w, k, 0.8);
        });

        // Weight-set algebra hot path.
        let mut acc = init.clone();
        b.bench_with_throughput(&format!("weightset_axpy/case{case}"), bytes, || {
            acc.axpy(0.001, &local);
        });
    }

    // Transport-level cost of one weight-set move — the real Eq. 11 c_w —
    // for the in-process backend (Arc bump + by-value submit) vs real
    // loopback sockets (frame encode → kernel → decode), plus a printed
    // measured-vs-modeled Eq. 11 comparison line.
    {
        use bptcnn::config::UpdateStrategy;
        use bptcnn::outer::{
            serve, InProcTransport, ServeOptions, SubmitMeta, SubmitMode, TcpTransport,
            TransferModel, Transport,
        };
        use std::sync::{Arc, Mutex};

        let cfg = NetworkConfig::table2_case(1);
        let bytes = cfg.weight_bytes() as f64;
        let init = Network::init(&cfg, 1).weights;

        let ps = Arc::new(Mutex::new(ParamServer::new(init.clone(), 1)));
        let mut t = InProcTransport::new(Arc::clone(&ps), 0);
        b.bench_with_throughput("transport/inproc_fetch", bytes, || {
            std::hint::black_box(t.fetch_global().unwrap());
        });
        b.bench_with_throughput("transport/inproc_cycle", 2.0 * bytes, || {
            let (w, base) = t.fetch_global().unwrap();
            let local = (*w).clone();
            let meta = SubmitMeta {
                mode: SubmitMode::Agwu,
                base,
                accuracy: 0.8,
                loss: 0.5,
                want_snapshot: false,
            };
            t.submit(local, &meta).unwrap();
        });
        drop(t);
        drop(ps);

        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let opts = ServeOptions {
            nodes: 1,
            update: UpdateStrategy::Agwu,
            ..ServeOptions::default()
        };
        let server = {
            let init = init.clone();
            std::thread::spawn(move || serve(listener, init, opts))
        };
        let mut t = TcpTransport::connect(&addr, 0).unwrap();
        b.bench_with_throughput("transport/tcp_loopback_fetch", bytes, || {
            std::hint::black_box(t.fetch_global().unwrap());
        });
        b.bench_with_throughput("transport/tcp_loopback_cycle", 2.0 * bytes, || {
            let (w, base) = t.fetch_global().unwrap();
            let local = (*w).clone();
            let meta = SubmitMeta {
                mode: SubmitMode::Agwu,
                base,
                accuracy: 0.8,
                loss: 0.5,
                want_snapshot: false,
            };
            t.submit(local, &meta).unwrap();
        });
        let st = t.stats();
        t.finish().unwrap();
        let report = server.join().unwrap().unwrap();

        // Eq. 11 comparison: measured loopback round (fetch + submit = the
        // 2·c_w of one node-iteration) vs the TransferModel on nominal 1 GbE.
        let per_fetch = st.fetch_wall_s / st.fetches.max(1) as f64;
        let per_submit = st.submit_wall_s / st.submits.max(1) as f64;
        let model = TransferModel::new(117.0e6, 100e-6); // ~1 GbE effective
        let modeled = 2.0 * model.transfer_time(cfg.weight_bytes());
        println!(
            "eq11/case1: measured loopback 2·c_w = {:.3} ms (fetch {:.3} + submit {:.3}), \
             modeled 1 GbE = {:.3} ms, wire/logical bytes = {:.2}",
            (per_fetch + per_submit) * 1e3,
            per_fetch * 1e3,
            per_submit * 1e3,
            modeled * 1e3,
            report.comm.wire_bytes as f64 / report.comm.bytes.max(1) as f64,
        );
    }

    // Pipelined worker loop vs the serialized fetch → train → submit cycle
    // under a throttled ~1 GbE link: the same driver, transport and update
    // rule, with only the staleness knob varied. Compute is a fixed-length
    // synthetic epoch so the compute/comm ratio is controlled (~50% comm
    // serialized) and the measured speedup isolates the overlap machinery.
    {
        use bptcnn::outer::{
            drive_worker, EpochOutcome, InProcTransport, LocalTrainer, Staleness, SubmitMode,
            ThrottledTransport, TransferModel, WorkerRunSummary,
        };
        use bptcnn::tensor::{Tensor, WeightSet};
        use std::cell::RefCell;
        use std::sync::{Arc, Mutex};

        /// Fixed-duration "epoch" (sleep), returning a nudged copy of the
        /// snapshot — compute cost without the noise of a real network.
        struct SpinTrainer {
            spin_s: f64,
            samples: usize,
        }
        impl LocalTrainer for SpinTrainer {
            fn train_epoch(&mut self, start: std::sync::Arc<WeightSet>) -> EpochOutcome {
                let t0 = std::time::Instant::now();
                std::thread::sleep(std::time::Duration::from_secs_f64(self.spin_s));
                let mut w = (*start).clone();
                w.tensors_mut()[0].data_mut()[0] += 0.01;
                EpochOutcome {
                    weights: w,
                    loss: 1.0,
                    accuracy: 0.5,
                    samples: self.samples.max(1),
                    compute_s: t0.elapsed().as_secs_f64(),
                }
            }
            fn add_samples(&mut self, range: std::ops::Range<usize>) {
                self.samples += range.len();
            }
            fn sample_count(&self) -> usize {
                self.samples
            }
        }

        const ITERS: usize = 6;
        const SPIN_S: f64 = 0.010;
        // 512 KB weight set: ~4.6 ms modeled transfer each way @ ~1 GbE.
        let init = WeightSet::new(vec![Tensor::zeros(&[131_072])]);
        let model = TransferModel::new(117.0e6, 100e-6); // ~1 GbE effective
        let stash: RefCell<Option<WorkerRunSummary>> = RefCell::new(None);

        let mut results = Vec::new();
        for (label, s) in [("serialized", 0usize), ("overlap_s1", 1), ("overlap_s2", 2)] {
            let r = b.bench(&format!("pipeline/{label}_cycle"), || {
                let ps = Arc::new(Mutex::new(ParamServer::new(init.clone(), 1)));
                let inner = InProcTransport::new(ps, 0);
                let mut t = ThrottledTransport::new(inner, model);
                let mut trainer = SpinTrainer { spin_s: SPIN_S, samples: 16 };
                let summary = drive_worker(
                    &mut t,
                    &mut trainer,
                    &[],
                    ITERS,
                    SubmitMode::Agwu,
                    Staleness(s),
                    false,
                )
                .expect("bench worker run");
                *stash.borrow_mut() = Some(summary);
            });
            let mean_s = r.mean_ns / 1e9;
            let sum = stash.borrow_mut().take().expect("summary recorded");
            println!(
                "pipeline/{label}: per-cycle {:.2} ms | busy {:.1} ms | stall {:.1} ms | \
                 overlap {:.1} ms | max in-flight {} | max staleness {} ({} refetches)",
                mean_s * 1e3 / ITERS as f64,
                sum.busy_s * 1e3,
                sum.stats.stall_wall_s * 1e3,
                sum.stats.overlap_wall_s * 1e3,
                sum.stats.max_inflight,
                sum.max_staleness,
                sum.staleness_refetches,
            );
            results.push((label, mean_s, sum));
        }

        // Acceptance: with comm ≥ 30% of the serialized cycle, the pipelined
        // loop must recover ≥ 1.3× (printed, mirroring the eq11 line; the
        // bench-smoke CI step greps this row).
        let (_, serial_s, serial_sum) = &results[0];
        let comm_s = serial_sum.stats.fetch_wall_s + serial_sum.stats.submit_wall_s;
        let comm_share = comm_s / serial_s.max(1e-12);
        for (label, overlap_s, _) in &results[1..] {
            let speedup = serial_s / overlap_s;
            let verdict = if comm_share < 0.30 {
                "SKIP (comm < 30% of cycle)"
            } else if speedup >= 1.3 {
                "PASS"
            } else {
                "FAIL"
            };
            println!(
                "pipeline/acceptance {label}: serialized {:.1} ms vs {:.1} ms -> {speedup:.2}x \
                 (comm {:.0}% of serialized cycle, target ≥1.3x) {verdict}",
                serial_s * 1e3,
                overlap_s * 1e3,
                comm_share * 100.0,
            );
        }
    }

    // IDPA schedule construction at paper scale.
    b.bench("idpa/30nodes_10batches_600k", || {
        let freqs: Vec<f64> = (0..30).map(|j| 1.6 + 0.05 * j as f64).collect();
        let mut p = IdpaPartitioner::new(600_000, 10, &freqs);
        p.run_with_oracle(|j| 1.0 / (1.0 + j as f64));
    });

    b.finish();
}
