//! Runtime benchmarks: XLA executable invocation latency and host↔device
//! conversion costs — the L3↔artifact boundary that the AGWU hot path pays
//! on every local iteration. Skips gracefully when artifacts are missing.

use std::sync::Arc;

use bptcnn::data::Dataset;
use bptcnn::nn::{Network, StepWorkspace};
use bptcnn::runtime::{find_model_dir, XlaService};
use bptcnn::tensor::Tensor;
use bptcnn::util::bench::Bench;

fn main() {
    let mut b = Bench::from_env("runtime");

    let Some(dir) = find_model_dir("quickstart") else {
        println!("runtime benches skipped: artifacts not built (run `make artifacts`)");
        return;
    };
    let service = match XlaService::start(&dir) {
        Ok(s) => s,
        Err(e) => {
            // Default builds stub out PJRT (`xla-pjrt` feature off).
            println!("runtime benches skipped: {e}");
            return;
        }
    };
    let h = service.handle();
    let cfg = h.manifest.config.clone();
    let ds = Arc::new(Dataset::synthetic(&cfg, 128, 0.2, 1));
    let weights = h.init_weights(1).unwrap();
    let (xv, yv, _) = ds.batch(0, cfg.batch_size);
    let x = Tensor::from_vec(&[cfg.batch_size, cfg.input_hw, cfg.input_hw, cfg.in_channels], xv.clone());
    let y = Tensor::from_vec(&[cfg.batch_size, cfg.num_classes], yv.clone());

    // Full train_step invocation (weights round-trip through literals).
    let batch_samples = cfg.batch_size as f64;
    let mut w = weights.clone();
    b.bench_with_throughput("xla/train_step_quickstart", batch_samples, || {
        let (nw, _, _) = h.train_step(w.clone(), x.clone(), y.clone(), 0.1).unwrap();
        w = nw;
    });
    b.bench_with_throughput("xla/eval_step_quickstart", batch_samples, || {
        h.eval_step(weights.clone(), x.clone(), y.clone()).unwrap();
    });

    // Native backend equivalents for the same step (the backend ablation),
    // on the allocation-free workspace path the epoch trainers use.
    let mut net = Network::with_weights(&cfg, weights.clone());
    let mut step_ws = StepWorkspace::new();
    b.bench_with_throughput("native/train_step_quickstart", batch_samples, || {
        net.train_batch_ws(&xv, &yv, cfg.batch_size, 0.1, &mut step_ws);
    });
    let net_eval = Network::with_weights(&cfg, weights.clone());
    let mut eval_ws = StepWorkspace::new();
    b.bench_with_throughput("native/eval_step_quickstart", batch_samples, || {
        net_eval.eval_batch_ws(&xv, &yv, cfg.batch_size, &mut eval_ws);
    });

    // e2e model, if built.
    if let Some(dir) = find_model_dir("e2e") {
        let service = XlaService::start(&dir).expect("service");
        let h = service.handle();
        let cfg = h.manifest.config.clone();
        let ds = Dataset::synthetic(&cfg, 64, 0.2, 2);
        let weights = h.init_weights(1).unwrap();
        let (xv, yv, _) = ds.batch(0, cfg.batch_size);
        let x = Tensor::from_vec(&[cfg.batch_size, cfg.input_hw, cfg.input_hw, cfg.in_channels], xv);
        let y = Tensor::from_vec(&[cfg.batch_size, cfg.num_classes], yv);
        let mut w = weights.clone();
        b.bench_with_throughput("xla/train_step_e2e", cfg.batch_size as f64, || {
            let (nw, _, _) = h.train_step(w.clone(), x.clone(), y.clone(), 0.1).unwrap();
            w = nw;
        });
    }

    b.finish();
}
