//! Inner-layer benchmarks: conv task decomposition + Algorithm-4.2
//! scheduling vs sequential execution (paper Fig. 14d micro-scale), task
//! granularity ablation, and DAG machinery overheads.

use bptcnn::inner::{conv2d_parallel, conv_task_dag, execute_dag, TaskDag};
use bptcnn::nn::ops::{self, ConvDims};
use bptcnn::util::bench::Bench;
use bptcnn::util::rng::Xoshiro256;
use bptcnn::util::threadpool::ThreadPool;

fn main() {
    let mut b = Bench::from_env("inner");
    let d = ConvDims { n: 8, h: 32, w: 32, c: 8, k: 3, co: 16 };
    let mut rng = Xoshiro256::new(1);
    let x: Vec<f32> = (0..d.x_len()).map(|_| rng.normal(0.0, 1.0) as f32).collect();
    let f: Vec<f32> = (0..d.f_len()).map(|_| rng.normal(0.0, 1.0) as f32).collect();
    let bias = vec![0.0f32; d.co];
    let flops = (d.y_len() * d.k * d.k * d.c * 2) as f64;

    // Sequential conv (the inner-layer baseline).
    let mut out = vec![0.0f32; d.y_len()];
    b.bench_with_throughput("conv_fwd/sequential", flops, || {
        ops::conv2d_same_fwd(&d, &x, &f, &bias, &mut out);
    });

    // Task-parallel conv at several granularities (Alg. 4.1 + 4.2).
    for threads in [1, 2, 4] {
        let pool = ThreadPool::new(threads);
        for rows in [1usize, 4, 16] {
            let mut out = vec![0.0f32; d.y_len()];
            b.bench_with_throughput(
                &format!("conv_fwd/tasks_{threads}t_{rows}rows"),
                flops,
                || {
                    conv2d_parallel(&pool, &d, &x, &f, &bias, &mut out, rows);
                },
            );
        }
    }

    // DAG construction + priority scheduling overhead (empty tasks).
    b.bench("dag/build_1k_tasks", || {
        let _ = conv_task_dag(&ConvDims { n: 32, h: 32, w: 32, c: 4, k: 3, co: 8 }, 1);
    });
    let pool = ThreadPool::new(4);
    b.bench("dag/schedule_512_noop_tasks", || {
        let mut dag: TaskDag<()> = TaskDag::new();
        for _ in 0..512 {
            dag.add("noop", 1.0, &[], ());
        }
        execute_dag(&pool, dag, |_| {});
    });

    b.finish();
}
