//! Inner-layer benchmarks: conv task decomposition + Algorithm-4.2
//! scheduling vs sequential execution (paper Fig. 14d micro-scale), the
//! packed-GEMM engine vs the seed's direct loops *and* vs the PR-1 unpacked
//! GEMM task path (the ISSUE-2 acceptance comparison), task granularity
//! ablation, gradient-reduction contention, and DAG machinery overheads.
//!
//! Headline rows: `conv_fwd_bwd/quickstart_*` — one conv layer at quickstart
//! shapes (batch 8, 8×8×1 → 4 filters, k=3), forward + backward, comparing
//! the seed direct loops, the serial packed-GEMM path, the **legacy** PR-1
//! task path (per-task heap scratch, `Arc::from` tensor copies, per-image
//! backward with a mutex-serialized gradient reduction — reconstructed here
//! from the retained legacy kernels) and the packed task path (worker
//! arenas, zero-copy dispatch, row-tile backward) on a 4-worker pool.
//! Acceptance: packed tasks ≥ 1.5× the legacy task row.
//!
//! `conv_bwd/e2e_*` is the contention-sensitive pair: backward only at the
//! heavier e2e shape, mutex-reduction legacy vs arena row-tile.

use std::sync::{Arc, Mutex};

use bptcnn::config::NetworkConfig;
use bptcnn::data::Dataset;
use bptcnn::inner::bp_tasks::conv_bwd_parallel;
use bptcnn::inner::conv_tasks::DisjointBuf;
use bptcnn::inner::{
    conv2d_parallel, conv_task_dag, execute_dag, parallel_train_step, TaskDag, TilePolicy,
};
use bptcnn::nn::ops::{self, ConvDims};
use bptcnn::nn::{Network, StepWorkspace};
use bptcnn::util::bench::Bench;
use bptcnn::util::rng::Xoshiro256;
use bptcnn::util::threadpool::ThreadPool;

struct ConvSetup {
    d: ConvDims,
    x: Vec<f32>,
    f: Vec<f32>,
    bias: Vec<f32>,
    dy: Vec<f32>,
}

fn setup(d: ConvDims, seed: u64) -> ConvSetup {
    let mut rng = Xoshiro256::new(seed);
    let mut rand = |n: usize| -> Vec<f32> {
        (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect()
    };
    ConvSetup {
        x: rand(d.x_len()),
        f: rand(d.f_len()),
        bias: rand(d.co),
        dy: rand(d.y_len()),
        d,
    }
}

/// fwd + bwd-filter + bwd-input FLOPs for one conv layer (the quantity the
/// acceptance criteria are measured over).
fn fwd_bwd_flops(d: &ConvDims) -> f64 {
    (d.y_len() * d.k * d.k * d.c * 2) as f64 * 3.0
}

// ---- legacy PR-1 task path (reconstructed baseline) -----------------------
//
// Reproduces the pre-ISSUE-2 cost profile: full-tensor `Arc::from` copies at
// dispatch, a fresh `vec![0.0; …]` im2col scratch in every task body, the
// unpacked blocked GEMM, and (backward) per-image tasks that allocate
// per-task partial gradients and serialize on one mutex to reduce them.

fn legacy_conv2d_parallel(
    pool: &ThreadPool,
    d: &ConvDims,
    x: &[f32],
    f: &[f32],
    bias: &[f32],
    out: &mut [f32],
    rows_per_task: usize,
) {
    let dag = conv_task_dag(d, rows_per_task);
    let shared = DisjointBuf::new(out);
    let row_len = d.w * d.co;
    let x: Arc<[f32]> = Arc::from(x);
    let f: Arc<[f32]> = Arc::from(f);
    let bias: Arc<[f32]> = Arc::from(bias);
    let dd = *d;
    let kkc = dd.k * dd.k * dd.c;
    execute_dag(pool, dag, move |_, task| {
        let offset = (task.n * dd.h + task.y0) * row_len;
        // SAFETY: row tiles of distinct tasks never overlap.
        let tile = unsafe { shared.slice_mut(offset, task.rows * row_len) };
        let mut cols = vec![0.0f32; task.rows * dd.w * kkc];
        ops::conv2d_same_rows_gemm(
            &dd, &x, &f, &bias, task.n, task.y0, task.rows, &mut cols, tile,
        );
    });
}

#[allow(clippy::too_many_arguments)]
fn legacy_conv_bwd_parallel(
    pool: &ThreadPool,
    d: &ConvDims,
    x: &[f32],
    f: &[f32],
    dy: &[f32],
    df: &mut [f32],
    db: &mut [f32],
    dx: &mut [f32],
) {
    let mut dag: TaskDag<usize> = TaskDag::new();
    let cost = (d.h * d.w * d.k * d.k * d.c * d.co) as f64;
    for n in 0..d.n {
        dag.add(format!("legacy_bwd[n{n}]"), cost, &[], n);
    }
    let per_image = ConvDims { n: 1, ..*d };
    let swapped = ConvDims { c: d.co, co: d.c, ..per_image };
    let flipped = ops::flip_transpose_filter(d, f);
    let zero_bias = vec![0.0f32; swapped.co];
    let x: Arc<[f32]> = Arc::from(x);
    let dy: Arc<[f32]> = Arc::from(dy);
    let _f: Arc<[f32]> = Arc::from(f);
    let partials: Arc<Mutex<(Vec<f32>, Vec<f32>)>> =
        Arc::new(Mutex::new((vec![0.0; d.f_len()], vec![0.0; d.co])));
    let dx_buf = DisjointBuf::new(dx);
    let x_img = d.h * d.w * d.c;
    let y_img = d.h * d.w * d.co;
    let p2 = Arc::clone(&partials);
    execute_dag(pool, dag, move |_, &n| {
        let xs = &x[n * x_img..(n + 1) * x_img];
        let dys = &dy[n * y_img..(n + 1) * y_img];
        let mut df_p = vec![0.0f32; per_image.f_len()];
        let mut db_p = vec![0.0f32; per_image.co];
        ops::conv2d_same_bwd_filter(&per_image, xs, dys, &mut df_p, &mut db_p);
        // SAFETY: image n exclusively owns its dx slice.
        let dxs = unsafe { dx_buf.slice_mut(n * x_img, x_img) };
        ops::conv2d_same_fwd(&swapped, dys, &flipped, &zero_bias, dxs);
        // Mutex-serialized reduction (the ISSUE-2 contention bug).
        let mut guard = p2.lock().unwrap();
        for (a, b) in guard.0.iter_mut().zip(df_p.iter()) {
            *a += b;
        }
        for (a, b) in guard.1.iter_mut().zip(db_p.iter()) {
            *a += b;
        }
    });
    let guard = partials.lock().unwrap();
    df.copy_from_slice(&guard.0);
    db.copy_from_slice(&guard.1);
}

/// Reconstructed ISSUE-3 legacy end-to-end step (the PR-2 spine): conv
/// layers ride the task-parallel packed engine, but the FC stack runs the
/// serial naive triple loops, every activation / delta / gradient buffer is
/// heap-allocated per batch (including the `conv_ins` input clones and the
/// full weight-set clone), and the loss allocates its softmax scratch. This
/// is the baseline the `train_step/packed_4t` acceptance row is measured
/// against.
fn legacy_train_step(
    pool: &ThreadPool,
    net: &mut Network,
    x: &[f32],
    y: &[f32],
    batch: usize,
    lr: f32,
    rows_per_task: usize,
) -> f32 {
    let cfg = net.cfg.clone();
    let hw = cfg.input_hw;
    let ws = net.weights.clone();
    let mut grads = net.weights.zeros_like();

    let mut conv_ins: Vec<Vec<f32>> = Vec::with_capacity(cfg.conv_layers);
    let mut conv_outs: Vec<Vec<f32>> = Vec::with_capacity(cfg.conv_layers);
    let mut cur = x.to_vec();
    for l in 0..cfg.conv_layers {
        let c = if l == 0 { cfg.in_channels } else { cfg.filters };
        let d = ConvDims { n: batch, h: hw, w: hw, c, k: cfg.kernel_hw, co: cfg.filters };
        conv_ins.push(cur.clone());
        let mut out = vec![0.0f32; d.y_len()];
        conv2d_parallel(
            pool,
            &d,
            &cur,
            ws.tensors()[2 * l].data(),
            ws.tensors()[2 * l + 1].data(),
            &mut out,
            rows_per_task,
        );
        ops::relu_fwd(&mut out);
        conv_outs.push(out.clone());
        cur = out;
    }

    let c = if cfg.conv_layers == 0 { cfg.in_channels } else { cfg.filters };
    let win = cfg.pool_window;
    let hp = hw / win;
    let mut pooled = vec![0.0f32; batch * hp * hp * c];
    ops::mean_pool_fwd(batch, hw, hw, c, win, &cur, &mut pooled);
    let mut feat = pooled.clone();
    let mut fan_in = hp * hp * c;
    let mut fc_outs: Vec<Vec<f32>> = Vec::with_capacity(cfg.fc_layers);
    let mut pi = 2 * cfg.conv_layers;
    for _ in 0..cfg.fc_layers {
        let w = &ws.tensors()[pi];
        let b = &ws.tensors()[pi + 1];
        pi += 2;
        let out_dim = w.shape()[1];
        let mut out = vec![0.0f32; batch * out_dim];
        ops::dense_fwd(batch, fan_in, out_dim, &feat, w.data(), b.data(), &mut out);
        ops::relu_fwd(&mut out);
        fc_outs.push(out.clone());
        feat = out;
        fan_in = out_dim;
    }
    let w_out = &ws.tensors()[pi];
    let b_out = &ws.tensors()[pi + 1];
    let mut logits = vec![0.0f32; batch * cfg.num_classes];
    ops::dense_fwd(batch, fan_in, cfg.num_classes, &feat, w_out.data(), b_out.data(), &mut logits);

    let mut dlogits = vec![0.0f32; batch * cfg.num_classes];
    let (loss, _) = ops::mse_softmax_loss(batch, cfg.num_classes, &logits, y, &mut dlogits);

    let pooled_dim = hp * hp * c;
    let out_w_idx = 2 * cfg.conv_layers + 2 * cfg.fc_layers;
    let last_feat: &[f32] = if cfg.fc_layers > 0 { &fc_outs[cfg.fc_layers - 1] } else { &pooled };
    let last_dim = if cfg.fc_layers > 0 { cfg.fc_neurons } else { pooled_dim };
    let mut dfeat = vec![0.0f32; batch * last_dim];
    {
        let gts = grads.tensors_mut();
        let (a, b) = gts.split_at_mut(out_w_idx + 1);
        ops::dense_bwd(
            batch,
            last_dim,
            cfg.num_classes,
            last_feat,
            ws.tensors()[out_w_idx].data(),
            &dlogits,
            &mut dfeat,
            a[out_w_idx].data_mut(),
            b[0].data_mut(),
        );
    }
    for l in (0..cfg.fc_layers).rev() {
        ops::relu_bwd(&fc_outs[l], &mut dfeat);
        let in_feat: &[f32] = if l == 0 { &pooled } else { &fc_outs[l - 1] };
        let in_dim = if l == 0 { pooled_dim } else { cfg.fc_neurons };
        let w_idx = 2 * cfg.conv_layers + 2 * l;
        let mut dprev = vec![0.0f32; batch * in_dim];
        {
            let gts = grads.tensors_mut();
            let (a, b) = gts.split_at_mut(w_idx + 1);
            ops::dense_bwd(
                batch,
                in_dim,
                cfg.fc_neurons,
                in_feat,
                ws.tensors()[w_idx].data(),
                &dfeat,
                &mut dprev,
                a[w_idx].data_mut(),
                b[0].data_mut(),
            );
        }
        dfeat = dprev;
    }
    let mut dconv = vec![0.0f32; batch * hw * hw * c];
    ops::mean_pool_bwd(batch, hw, hw, c, win, &dfeat, &mut dconv);

    for l in (0..cfg.conv_layers).rev() {
        ops::relu_bwd(&conv_outs[l], &mut dconv);
        let cin = if l == 0 { cfg.in_channels } else { cfg.filters };
        let d = ConvDims { n: batch, h: hw, w: hw, c: cin, k: cfg.kernel_hw, co: cfg.filters };
        let w_idx = 2 * l;
        let mut dprev = if l > 0 { Some(vec![0.0f32; d.x_len()]) } else { None };
        {
            let gts = grads.tensors_mut();
            let (a, b) = gts.split_at_mut(w_idx + 1);
            conv_bwd_parallel(
                pool,
                &d,
                &conv_ins[l],
                ws.tensors()[w_idx].data(),
                &dconv,
                a[w_idx].data_mut(),
                b[0].data_mut(),
                dprev.as_deref_mut(),
                rows_per_task,
            );
        }
        if let Some(dp) = dprev {
            dconv = dp;
        }
    }

    net.weights.axpy(-lr, &grads);
    loss
}

/// Which conv implementation a `conv_fwd_bwd/*` row exercises.
enum ConvImpl<'a> {
    /// The seed's direct loops (the original acceptance baseline).
    SeedNaive,
    /// Serial im2col + packed micro-kernel GEMM.
    PackedSerial,
    /// Legacy PR-1 task path: unpacked GEMM, per-task allocs, Arc copies,
    /// per-image mutex-reduced backward.
    LegacyTasks(&'a ThreadPool),
    /// ISSUE-2 engine: packed GEMM tiles, worker arenas, zero-copy dispatch,
    /// row-tile backward with arena-reduced gradients.
    PackedTasks(&'a ThreadPool),
}

fn bench_conv_fwd_bwd(b: &mut Bench, label: &str, s: &ConvSetup, imp: ConvImpl<'_>) {
    let d = &s.d;
    let flops = fwd_bwd_flops(d);
    let mut out = vec![0.0f32; d.y_len()];
    let mut df = vec![0.0f32; d.f_len()];
    let mut db = vec![0.0f32; d.co];
    let mut dx = vec![0.0f32; d.x_len()];
    let rows = (d.h / 2).max(1); // 2 row-tiles per image
    match imp {
        ConvImpl::SeedNaive => {
            b.bench_with_throughput(&format!("conv_fwd_bwd/{label}"), flops, || {
                ops::conv2d_same_fwd_naive(d, &s.x, &s.f, &s.bias, &mut out);
                ops::conv2d_same_bwd_filter_naive(d, &s.x, &s.dy, &mut df, &mut db);
                ops::conv2d_same_bwd_input_naive(d, &s.dy, &s.f, &mut dx);
            });
        }
        ConvImpl::PackedSerial => {
            b.bench_with_throughput(&format!("conv_fwd_bwd/{label}"), flops, || {
                ops::conv2d_same_fwd(d, &s.x, &s.f, &s.bias, &mut out);
                ops::conv2d_same_bwd_filter(d, &s.x, &s.dy, &mut df, &mut db);
                ops::conv2d_same_bwd_input(d, &s.dy, &s.f, &mut dx);
            });
        }
        ConvImpl::LegacyTasks(pool) => {
            b.bench_with_throughput(&format!("conv_fwd_bwd/{label}"), flops, || {
                legacy_conv2d_parallel(pool, d, &s.x, &s.f, &s.bias, &mut out, rows);
                legacy_conv_bwd_parallel(pool, d, &s.x, &s.f, &s.dy, &mut df, &mut db, &mut dx);
            });
        }
        ConvImpl::PackedTasks(pool) => {
            b.bench_with_throughput(&format!("conv_fwd_bwd/{label}"), flops, || {
                conv2d_parallel(pool, d, &s.x, &s.f, &s.bias, &mut out, rows);
                let dx = Some(&mut dx[..]);
                conv_bwd_parallel(pool, d, &s.x, &s.f, &s.dy, &mut df, &mut db, dx, rows);
            });
        }
    }
}

fn main() {
    let mut b = Bench::from_env("inner");

    // ---- acceptance comparison: quickstart conv layer, fwd+bwd -----------
    // quickstart: batch 8, 8×8 input, 1→4 channels, 3×3 kernels.
    let quickstart = setup(ConvDims { n: 8, h: 8, w: 8, c: 1, k: 3, co: 4 }, 1);
    let pool4 = ThreadPool::new(4);
    bench_conv_fwd_bwd(&mut b, "quickstart_seed_naive", &quickstart, ConvImpl::SeedNaive);
    bench_conv_fwd_bwd(&mut b, "quickstart_packed_serial", &quickstart, ConvImpl::PackedSerial);
    bench_conv_fwd_bwd(
        &mut b,
        "quickstart_gemm_legacy_tasks_4t",
        &quickstart,
        ConvImpl::LegacyTasks(&pool4),
    );
    bench_conv_fwd_bwd(
        &mut b,
        "quickstart_packed_tasks_4t",
        &quickstart,
        ConvImpl::PackedTasks(&pool4),
    );

    // Same comparison at the heavier e2e layer-1 shape (8→8 channels, 16×16).
    let e2e = setup(ConvDims { n: 32, h: 16, w: 16, c: 8, k: 3, co: 8 }, 2);
    bench_conv_fwd_bwd(&mut b, "e2e_seed_naive", &e2e, ConvImpl::SeedNaive);
    bench_conv_fwd_bwd(&mut b, "e2e_packed_serial", &e2e, ConvImpl::PackedSerial);
    bench_conv_fwd_bwd(&mut b, "e2e_gemm_legacy_tasks_4t", &e2e, ConvImpl::LegacyTasks(&pool4));
    bench_conv_fwd_bwd(&mut b, "e2e_packed_tasks_4t", &e2e, ConvImpl::PackedTasks(&pool4));

    // ---- gradient-reduction contention (backward only, many small tasks) --
    {
        let d = e2e.d;
        let bwd_flops = (d.y_len() * d.k * d.k * d.c * 2) as f64 * 2.0;
        let mut df = vec![0.0f32; d.f_len()];
        let mut db = vec![0.0f32; d.co];
        let mut dx = vec![0.0f32; d.x_len()];
        b.bench_with_throughput("conv_bwd/e2e_mutex_legacy_4t", bwd_flops, || {
            let (x, f, dy) = (&e2e.x, &e2e.f, &e2e.dy);
            legacy_conv_bwd_parallel(&pool4, &d, x, f, dy, &mut df, &mut db, &mut dx);
        });
        b.bench_with_throughput("conv_bwd/e2e_rowtile_4t", bwd_flops, || {
            let (x, f, dy) = (&e2e.x, &e2e.f, &e2e.dy);
            conv_bwd_parallel(&pool4, &d, x, f, dy, &mut df, &mut db, Some(&mut dx), 4);
        });
    }

    // ---- end-to-end train step: ISSUE-3 acceptance comparison -------------
    // Table-2-flavored shape (the paper's nets are FC-heavy): conv 2×8ch on
    // 16×16 plus fc 2×256 → packed+workspace+parallel-FC step vs the
    // reconstructed legacy spine (serial naive dense, per-batch allocations,
    // weight-set clone). Acceptance: packed ≥ 1.3× legacy at 4 threads.
    {
        let cfg = NetworkConfig {
            name: "bench_step".into(),
            input_hw: 16,
            in_channels: 1,
            conv_layers: 2,
            filters: 8,
            kernel_hw: 3,
            fc_layers: 2,
            fc_neurons: 256,
            num_classes: 10,
            batch_size: 32,
            pool_window: 2,
        };
        let ds = Dataset::synthetic(&cfg, 64, 0.2, 5);
        let (x, y, _) = ds.batch(0, cfg.batch_size);
        let flops = cfg.flops_per_sample() * cfg.batch_size as f64;
        let conv_rows = cfg.input_hw / 2; // two row tiles per image
        let mut legacy_net = Network::init(&cfg, 9);
        b.bench_with_throughput("train_step/legacy_4t", flops, || {
            legacy_train_step(&pool4, &mut legacy_net, &x, &y, cfg.batch_size, 0.02, conv_rows);
        });
        let mut packed_net = Network::init(&cfg, 9);
        let mut step_ws = StepWorkspace::new();
        b.bench_with_throughput("train_step/packed_4t", flops, || {
            parallel_train_step(
                &pool4,
                &mut packed_net,
                &x,
                &y,
                cfg.batch_size,
                0.02,
                TilePolicy::grid2d(conv_rows),
                &mut step_ws,
            );
        });
        // Serial workspace step (no pool): isolates the packed-dense +
        // zero-alloc win from the inner-parallel win.
        let mut serial_net = Network::init(&cfg, 9);
        let mut serial_ws = StepWorkspace::new();
        b.bench_with_throughput("train_step/serial_ws", flops, || {
            serial_net.train_batch_ws(&x, &y, cfg.batch_size, 0.02, &mut serial_ws);
        });
        // The ISSUE-5 conv autotune row: the same large-batch conv step
        // under TilePolicy::Auto. The bench warmup doubles as the tuner's
        // exploration window, so the measured rows are the locked plans.
        // Acceptance: auto ≥ 0.95× the best static policy above.
        let mut auto_net = Network::init(&cfg, 9);
        let mut auto_ws = StepWorkspace::new();
        b.bench_with_throughput("train_step/auto_4t", flops, || {
            parallel_train_step(
                &pool4,
                &mut auto_net,
                &x,
                &y,
                cfg.batch_size,
                0.02,
                TilePolicy::auto(conv_rows),
                &mut auto_ws,
            );
        });
        println!("train_step/auto_4t {}", auto_net.tuning_report());
        // ISSUE-7 checker-overhead row: the same packed step, labelled by
        // whether the `chk` runtime claim cross-check is compiled in. The
        // default build must keep the `_chk_off` row within 1% of
        // `train_step/packed_4t` (the claim plumbing is a dead `None` field
        // without the feature); compare `_chk_on` vs `_chk_off` across a
        // `--features chk` run to read the checker's true cost.
        let chk_label = if cfg!(feature = "chk") {
            "train_step/packed_4t_chk_on"
        } else {
            "train_step/packed_4t_chk_off"
        };
        let mut chk_net = Network::init(&cfg, 9);
        let mut chk_ws = StepWorkspace::new();
        b.bench_with_throughput(chk_label, flops, || {
            parallel_train_step(
                &pool4,
                &mut chk_net,
                &x,
                &y,
                cfg.batch_size,
                0.02,
                TilePolicy::grid2d(conv_rows),
                &mut chk_ws,
            );
        });
    }

    // ---- 2D row×column tiling: Table-2 cases 5–7 (2000-neuron FC, small
    // batch) — the ISSUE-4 acceptance pair plus the ISSUE-5 auto rows.
    // Row-only tiling leaves ≤ batch tiles per FC stage, so an 8-worker
    // pool mostly idles; the 2D grid splits the packed-B panel space across
    // workers; Auto searches around the static plan online. Acceptance:
    // 2D ≥ 1.5× row-only at batch ≤ 8 / 8 threads, auto ≥ 1.1× row-only at
    // batch 4 / 8 threads after the exploration window (the bench warmup).
    {
        let cfg = NetworkConfig {
            name: "case6_fc".into(),
            input_hw: 16,
            in_channels: 1,
            conv_layers: 1,
            filters: 8,
            kernel_hw: 3,
            fc_layers: 2,
            fc_neurons: 2000,
            num_classes: 10,
            batch_size: 8,
            pool_window: 2,
        };
        let pool8 = ThreadPool::new(8);
        let ds = Dataset::synthetic(&cfg, 16, 0.2, 11);
        let conv_rows = cfg.input_hw / 2;
        let mut plan_table = String::new();
        for batch in [4usize, 8] {
            let (x, y, _) = ds.batch(0, batch);
            let flops = cfg.flops_per_sample() * batch as f64;
            for (tname, pool) in [("4t", &pool4), ("8t", &pool8)] {
                let mut net_row = Network::init(&cfg, 21);
                let mut ws_row = StepWorkspace::new();
                b.bench_with_throughput(
                    &format!("fc2000_step/b{batch}_rowonly_{tname}"),
                    flops,
                    || {
                        parallel_train_step(
                            pool,
                            &mut net_row,
                            &x,
                            &y,
                            batch,
                            0.01,
                            TilePolicy::rows_only(conv_rows),
                            &mut ws_row,
                        );
                    },
                );
                let mut net_2d = Network::init(&cfg, 21);
                let mut ws_2d = StepWorkspace::new();
                b.bench_with_throughput(
                    &format!("fc2000_step/b{batch}_2d_{tname}"),
                    flops,
                    || {
                        parallel_train_step(
                            pool,
                            &mut net_2d,
                            &x,
                            &y,
                            batch,
                            0.01,
                            TilePolicy::grid2d(conv_rows),
                            &mut ws_2d,
                        );
                    },
                );
                let mut net_auto = Network::init(&cfg, 21);
                let mut ws_auto = StepWorkspace::new();
                b.bench_with_throughput(
                    &format!("fc2000_step/b{batch}_auto_{tname}"),
                    flops,
                    || {
                        parallel_train_step(
                            pool,
                            &mut net_auto,
                            &x,
                            &y,
                            batch,
                            0.01,
                            TilePolicy::auto(conv_rows),
                            &mut ws_auto,
                        );
                    },
                );
                plan_table = format!(
                    "fc2000_step/b{batch}_auto_{tname} {}",
                    net_auto.tuning_report()
                );
            }
        }
        // Final per-stage plan table (last auto row: b8 at 8 threads) so
        // regressions in tuning choices are visible in CI logs.
        println!("{plan_table}");
    }

    // ---- forward-only sweeps (granularity/thread ablation) ---------------
    let d = ConvDims { n: 8, h: 32, w: 32, c: 8, k: 3, co: 16 };
    let s = setup(d, 3);
    let flops = (d.y_len() * d.k * d.k * d.c * 2) as f64;

    let mut out = vec![0.0f32; d.y_len()];
    b.bench_with_throughput("conv_fwd/seed_naive", flops, || {
        ops::conv2d_same_fwd_naive(&d, &s.x, &s.f, &s.bias, &mut out);
    });
    b.bench_with_throughput("conv_fwd/packed_serial", flops, || {
        ops::conv2d_same_fwd(&d, &s.x, &s.f, &s.bias, &mut out);
    });

    // Task-parallel conv at several granularities (Alg. 4.1 + 4.2).
    for threads in [1, 2, 4] {
        let pool = ThreadPool::new(threads);
        for rows in [1usize, 4, 16] {
            let mut out = vec![0.0f32; d.y_len()];
            b.bench_with_throughput(
                &format!("conv_fwd/tasks_{threads}t_{rows}rows"),
                flops,
                || {
                    conv2d_parallel(&pool, &d, &s.x, &s.f, &s.bias, &mut out, rows);
                },
            );
        }
    }

    // DAG construction + priority scheduling overhead (empty tasks).
    b.bench("dag/build_1k_tasks", || {
        let _ = conv_task_dag(&ConvDims { n: 32, h: 32, w: 32, c: 4, k: 3, co: 8 }, 1);
    });
    let pool = ThreadPool::new(4);
    b.bench("dag/schedule_512_noop_tasks", || {
        let mut dag: TaskDag<()> = TaskDag::new();
        for _ in 0..512 {
            dag.add("noop", 1.0, &[], ());
        }
        execute_dag(&pool, dag, |_, _| {});
    });

    b.finish();
}
