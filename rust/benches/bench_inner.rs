//! Inner-layer benchmarks: conv task decomposition + Algorithm-4.2
//! scheduling vs sequential execution (paper Fig. 14d micro-scale), the
//! im2col+GEMM fast path vs the seed's direct loops (the PR-1 acceptance
//! comparison), task granularity ablation, and DAG machinery overheads.
//!
//! Headline rows: `conv_fwd_bwd/quickstart_*` — one conv layer at quickstart
//! shapes (batch 8, 8×8×1 → 4 filters, k=3), forward + filter-gradient
//! backward, comparing the seed direct loops, the serial im2col+GEMM path,
//! and the Algorithm-4.1/4.2 task-parallel path on a 4-worker pool.

use bptcnn::inner::bp_tasks::conv_bwd_parallel;
use bptcnn::inner::{conv2d_parallel, conv_task_dag, execute_dag, TaskDag};
use bptcnn::nn::ops::{self, ConvDims};
use bptcnn::util::bench::Bench;
use bptcnn::util::rng::Xoshiro256;
use bptcnn::util::threadpool::ThreadPool;

struct ConvSetup {
    d: ConvDims,
    x: Vec<f32>,
    f: Vec<f32>,
    bias: Vec<f32>,
    dy: Vec<f32>,
}

fn setup(d: ConvDims, seed: u64) -> ConvSetup {
    let mut rng = Xoshiro256::new(seed);
    let mut rand = |n: usize| -> Vec<f32> {
        (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect()
    };
    ConvSetup {
        x: rand(d.x_len()),
        f: rand(d.f_len()),
        bias: rand(d.co),
        dy: rand(d.y_len()),
        d,
    }
}

/// fwd + bwd-filter + bwd-input FLOPs for one conv layer (the quantity the
/// ≥2× acceptance criterion is measured over).
fn fwd_bwd_flops(d: &ConvDims) -> f64 {
    (d.y_len() * d.k * d.k * d.c * 2) as f64 * 3.0
}

/// Which conv implementation a `conv_fwd_bwd/*` row exercises.
enum ConvImpl<'a> {
    /// The seed's direct loops (the ≥2× acceptance baseline).
    SeedNaive,
    /// Serial im2col + blocked GEMM.
    GemmSerial,
    /// Algorithm-4.1/4.2 task-parallel GEMM tiles on the given pool.
    GemmTasks(&'a ThreadPool),
}

fn bench_conv_fwd_bwd(b: &mut Bench, label: &str, s: &ConvSetup, imp: ConvImpl<'_>) {
    let d = &s.d;
    let flops = fwd_bwd_flops(d);
    let mut out = vec![0.0f32; d.y_len()];
    let mut df = vec![0.0f32; d.f_len()];
    let mut db = vec![0.0f32; d.co];
    let mut dx = vec![0.0f32; d.x_len()];
    match imp {
        ConvImpl::SeedNaive => {
            b.bench_with_throughput(&format!("conv_fwd_bwd/{label}"), flops, || {
                ops::conv2d_same_fwd_naive(d, &s.x, &s.f, &s.bias, &mut out);
                ops::conv2d_same_bwd_filter_naive(d, &s.x, &s.dy, &mut df, &mut db);
                ops::conv2d_same_bwd_input_naive(d, &s.dy, &s.f, &mut dx);
            });
        }
        ConvImpl::GemmSerial => {
            b.bench_with_throughput(&format!("conv_fwd_bwd/{label}"), flops, || {
                ops::conv2d_same_fwd(d, &s.x, &s.f, &s.bias, &mut out);
                ops::conv2d_same_bwd_filter(d, &s.x, &s.dy, &mut df, &mut db);
                ops::conv2d_same_bwd_input(d, &s.dy, &s.f, &mut dx);
            });
        }
        ConvImpl::GemmTasks(pool) => {
            let rows = (d.h / 2).max(1); // 2 row-tiles per image
            b.bench_with_throughput(&format!("conv_fwd_bwd/{label}"), flops, || {
                conv2d_parallel(pool, d, &s.x, &s.f, &s.bias, &mut out, rows);
                conv_bwd_parallel(pool, d, &s.x, &s.f, &s.dy, &mut df, &mut db, Some(&mut dx));
            });
        }
    }
}

fn main() {
    let mut b = Bench::from_env("inner");

    // ---- acceptance comparison: quickstart conv layer, fwd+bwd -----------
    // quickstart: batch 8, 8×8 input, 1→4 channels, 3×3 kernels.
    let quickstart = setup(ConvDims { n: 8, h: 8, w: 8, c: 1, k: 3, co: 4 }, 1);
    let pool4 = ThreadPool::new(4);
    bench_conv_fwd_bwd(&mut b, "quickstart_seed_naive", &quickstart, ConvImpl::SeedNaive);
    bench_conv_fwd_bwd(&mut b, "quickstart_gemm_serial", &quickstart, ConvImpl::GemmSerial);
    bench_conv_fwd_bwd(
        &mut b,
        "quickstart_gemm_tasks_4t",
        &quickstart,
        ConvImpl::GemmTasks(&pool4),
    );

    // Same comparison at the heavier e2e layer-1 shape (8→8 channels, 16×16).
    let e2e = setup(ConvDims { n: 32, h: 16, w: 16, c: 8, k: 3, co: 8 }, 2);
    bench_conv_fwd_bwd(&mut b, "e2e_seed_naive", &e2e, ConvImpl::SeedNaive);
    bench_conv_fwd_bwd(&mut b, "e2e_gemm_serial", &e2e, ConvImpl::GemmSerial);
    bench_conv_fwd_bwd(&mut b, "e2e_gemm_tasks_4t", &e2e, ConvImpl::GemmTasks(&pool4));

    // ---- forward-only sweeps (granularity/thread ablation) ---------------
    let d = ConvDims { n: 8, h: 32, w: 32, c: 8, k: 3, co: 16 };
    let s = setup(d, 3);
    let flops = (d.y_len() * d.k * d.k * d.c * 2) as f64;

    let mut out = vec![0.0f32; d.y_len()];
    b.bench_with_throughput("conv_fwd/seed_naive", flops, || {
        ops::conv2d_same_fwd_naive(&d, &s.x, &s.f, &s.bias, &mut out);
    });
    b.bench_with_throughput("conv_fwd/gemm_serial", flops, || {
        ops::conv2d_same_fwd(&d, &s.x, &s.f, &s.bias, &mut out);
    });

    // Task-parallel conv at several granularities (Alg. 4.1 + 4.2).
    for threads in [1, 2, 4] {
        let pool = ThreadPool::new(threads);
        for rows in [1usize, 4, 16] {
            let mut out = vec![0.0f32; d.y_len()];
            b.bench_with_throughput(
                &format!("conv_fwd/tasks_{threads}t_{rows}rows"),
                flops,
                || {
                    conv2d_parallel(&pool, &d, &s.x, &s.f, &s.bias, &mut out, rows);
                },
            );
        }
    }

    // DAG construction + priority scheduling overhead (empty tasks).
    b.bench("dag/build_1k_tasks", || {
        let _ = conv_task_dag(&ConvDims { n: 32, h: 32, w: 32, c: 4, k: 3, co: 8 }, 1);
    });
    let pool = ThreadPool::new(4);
    b.bench("dag/schedule_512_noop_tasks", || {
        let mut dag: TaskDag<()> = TaskDag::new();
        for _ in 0..512 {
            dag.add("noop", 1.0, &[], ());
        }
        execute_dag(&pool, dag, |_| {});
    });

    b.finish();
}
