//! Simulator benchmarks: DES engine event throughput and full paper-figure
//! sweep timings (the cost of regenerating Fig. 12 / Fig. 14 / Fig. 15).

use bptcnn::config::{ClusterConfig, PartitionStrategy, UpdateStrategy};
use bptcnn::sim::{simulate, simulate_algorithm, Algorithm, EventQueue, SimConfig};
use bptcnn::util::bench::Bench;

fn main() {
    let mut b = Bench::from_env("sim");

    // Raw event-queue throughput.
    b.bench_with_throughput("event_queue/push_pop_10k", 10_000.0, || {
        let mut q: EventQueue<u32> = EventQueue::new();
        for i in 0..10_000u32 {
            q.schedule_at((i as u64).wrapping_mul(0x9E37_79B9) % 1_000_000, i);
        }
        while q.pop().is_some() {}
    });

    // One full AGWU simulation at paper scale (30 nodes × 100 iterations —
    // 3000 events + allocation).
    let cfg = SimConfig {
        cluster: ClusterConfig::heterogeneous(30, 7),
        samples: 600_000,
        iterations: 100,
        ..SimConfig::paper_default()
    };
    let events = (30 * 100) as f64;
    b.bench_with_throughput("simulate/agwu_idpa_30n_100k", events, || {
        simulate(&cfg);
    });
    let sgwu_cfg = SimConfig {
        update: UpdateStrategy::Sgwu,
        partition: PartitionStrategy::Udpa,
        ..cfg.clone()
    };
    b.bench_with_throughput("simulate/sgwu_udpa_30n_100k", events, || {
        simulate(&sgwu_cfg);
    });

    // Baseline models.
    for alg in [Algorithm::TensorflowLike, Algorithm::DistBeliefLike, Algorithm::DcCnnLike] {
        b.bench(&format!("simulate/{}", alg.name().to_lowercase()), || {
            simulate_algorithm(alg, &cfg);
        });
    }

    b.finish();
}
