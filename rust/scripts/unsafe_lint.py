#!/usr/bin/env python3
"""Fail when an `unsafe` site lacks a safety justification.

Companion to `#![deny(unsafe_op_in_unsafe_fn)]` in src/lib.rs: the compiler
forces every unsafe operation into an explicit `unsafe {}` block even inside
`unsafe fn`, and this lint forces every such block (and every `unsafe impl`
/ `unsafe fn`) to carry the justification itself:

* `unsafe fn` declarations need a `# Safety` section in their doc comment
  (or an inline `SAFETY:` comment for private helpers);
* `unsafe impl` and `unsafe {}` blocks need a `// SAFETY:` comment on the
  same line or within the preceding LOOKBACK lines (one comment may cover a
  short run of related blocks).

Runs in CI next to the tier-1 tests (`python3 scripts/unsafe_lint.py` from
`rust/`); exits 1 listing every undocumented site.
"""

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent  # rust/
SCAN_DIRS = ("src", "tests", "benches")
LOOKBACK = 8
UNSAFE_RE = re.compile(r"\bunsafe\b")


def strip_comments(line: str) -> str:
    """Drop `//` comments (incl. doc comments) so prose mentioning `unsafe`
    never counts as a site. Block comments and `//` inside string literals
    do not occur on unsafe lines in this codebase."""
    idx = line.find("//")
    return line if idx < 0 else line[:idx]


def has_safety_doc(lines: list, decl: int) -> bool:
    """Walk up through the decl's doc comments / attributes / blank lines
    looking for a `# Safety` section."""
    i = decl - 1
    while i >= 0:
        s = lines[i].strip()
        if s.startswith(("///", "//!", "#[", "//")) or not s:
            if "# Safety" in s:
                return True
            i -= 1
            continue
        break
    return False


def has_safety_comment(lines: list, at: int) -> bool:
    lo = max(0, at - LOOKBACK)
    return any("SAFETY:" in lines[j] for j in range(lo, at + 1))


def main() -> int:
    files = []
    for sub in SCAN_DIRS:
        d = ROOT / sub
        if d.is_dir():
            files.extend(sorted(d.rglob("*.rs")))
    bad = []
    for path in files:
        lines = path.read_text(encoding="utf-8").splitlines()
        for i, raw in enumerate(lines):
            code = strip_comments(raw)
            for m in UNSAFE_RE.finditer(code):
                rest = code[m.end():].lstrip()
                if rest.startswith("fn "):
                    ok = has_safety_doc(lines, i) or has_safety_comment(lines, i)
                else:  # `unsafe impl` or an `unsafe {}` block/expression
                    ok = has_safety_comment(lines, i)
                if not ok:
                    bad.append(f"{path.relative_to(ROOT)}:{i + 1}: {raw.strip()}")
    if bad:
        print("undocumented unsafe (add `// SAFETY: ...` or a `# Safety` doc section):")
        for b in bad:
            print(f"  {b}")
        return 1
    print(f"unsafe_lint: every unsafe site documented ({len(files)} files scanned)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
