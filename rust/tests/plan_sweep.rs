//! Offline schedule-soundness sweep: enumerate the tile planner's output
//! space over a grid of GEMM/conv shapes (including the paper's Table-2
//! ragged cases), lower every emitted plan to access claims and statically
//! [`verify`](bptcnn::inner::check::verify) it — write-write and read-write
//! overlaps between unordered tasks are planner bugs and fail here without
//! ever executing a kernel. This is the exhaustive counterpart of the
//! sampled proptest parity suite: it proves the *schedules* sound, the
//! proptests prove the *values* right.

use bptcnn::inner::check::{self, Buf};
use bptcnn::inner::{
    conv_bwd_claims, conv_bwd_dag, conv_fwd_claims, conv_lower_claims, conv_lower_dag,
    conv_tile_dag, dense_bwd_claims, dense_bwd_dag, dense_bwd_fused_claims, dense_fwd_claims,
    plan_cols_for_rows_with_floor, plan_tile_grid, plan_tile_grid_with_floor, row_tile_dag,
    tile2_dag,
};
use bptcnn::nn::ops::ConvDims;

/// Verify one dense stage pair (forward + backward) at an explicit planner
/// floor; returns how many plans were checked.
fn sweep_dense_shape(m: usize, k: usize, n: usize, workers: usize, floor: usize) -> usize {
    let ctx = format!("m={m} k={k} n={n} workers={workers} floor={floor}");
    // Forward: 2D row×panel tiles over the (m, n) output.
    let grid = plan_tile_grid_with_floor(m, k, n, workers, 1, floor);
    let dag = tile2_dag(m, n, &grid, 1.0, "dense_fwd");
    let claims = dense_fwd_claims(n, &dag);
    check::verify(&dag, &claims).unwrap_or_else(|v| panic!("fwd {ctx}: {v}"));
    assert!(check::max_extent(&claims, Buf::Out) <= m * n, "fwd {ctx}: claim outside out");

    // Backward: fused row tiles, or the two-phase Grad→Dx DAG when a grid
    // column-splits — exactly the dispatch predicate of dense_bwd_parallel.
    let dy_grid = plan_tile_grid_with_floor(m, k, n, workers, 1, floor);
    let dx_grid = plan_cols_for_rows_with_floor(
        dy_grid.rows_per_tile,
        dy_grid.row_tiles,
        n,
        k,
        workers,
        floor,
    );
    if dy_grid.panel_tiles == 1 && dx_grid.panel_tiles == 1 {
        let dag = row_tile_dag(m, dy_grid.rows_per_tile, 1.0, "dense_bwd");
        let claims = dense_bwd_fused_claims(k, n, &dag);
        check::verify(&dag, &claims).unwrap_or_else(|v| panic!("bwd fused {ctx}: {v}"));
        assert!(check::max_extent(&claims, Buf::Dy) <= m * n, "bwd fused {ctx}: dy overrun");
        assert!(check::max_extent(&claims, Buf::Out) <= m * k, "bwd fused {ctx}: dx overrun");
    } else {
        let dag = dense_bwd_dag(m, k, n, &dy_grid, &dx_grid);
        let claims = dense_bwd_claims(k, n, &dag);
        check::verify(&dag, &claims).unwrap_or_else(|v| panic!("bwd 2d {ctx}: {v}"));
        assert!(check::max_extent(&claims, Buf::Dy) <= m * n, "bwd 2d {ctx}: dy overrun");
        assert!(check::max_extent(&claims, Buf::Out) <= m * k, "bwd 2d {ctx}: dx overrun");
    }
    2
}

/// Every plan the dense planner emits over the shape grid is race-free.
/// Shapes include single rows/columns, ragged panels (n = 10, 19) and the
/// Table-2 wide-FC extremes; floors span "split everything" to "never
/// split".
#[test]
fn dense_plan_sweep_is_race_free() {
    let mut plans = 0usize;
    for &m in &[1usize, 2, 3, 4, 8, 32] {
        for &k in &[9usize, 27, 250, 2000] {
            for &n in &[1usize, 8, 10, 19, 250, 2000] {
                for &workers in &[1usize, 2, 4, 8] {
                    for &floor in &[1usize, 32 * 1024, 1 << 20] {
                        plans += sweep_dense_shape(m, k, n, workers, floor);
                    }
                }
            }
        }
    }
    assert!(plans >= 3000, "sweep shrank to {plans} plans — grid eroded?");
}

/// Verify one conv layer's forward and both backward variants (with and
/// without dx) at an explicit floor; returns how many plans were checked.
fn sweep_conv_shape(d: &ConvDims, workers: usize, floor: usize) -> usize {
    let ctx = format!(
        "n={} h={} w={} c={} k={} co={} workers={workers} floor={floor}",
        d.n, d.h, d.w, d.c, d.k, d.co
    );
    let kk = d.k * d.k * d.c;
    // Forward: row-only tile DAG, or the Lower → Tile column-split DAG —
    // the dispatch predicate of conv2d_parallel_packed_ws.
    let grid = plan_tile_grid_with_floor(d.n * d.h, kk, d.co, workers, 1, floor);
    if grid.panel_tiles <= 1 {
        let dag = conv_tile_dag(d, &grid);
        let claims = conv_fwd_claims(d, &dag);
        check::verify(&dag, &claims).unwrap_or_else(|v| panic!("conv fwd {ctx}: {v}"));
        assert!(check::max_extent(&claims, Buf::Out) <= d.y_len(), "conv fwd {ctx}: overrun");
    } else {
        let (dag, total) = conv_lower_dag(d, &grid);
        let claims = conv_lower_claims(d, &dag);
        check::verify(&dag, &claims).unwrap_or_else(|v| panic!("conv fwd 2d {ctx}: {v}"));
        assert!(check::max_extent(&claims, Buf::Out) <= d.y_len(), "conv fwd {ctx}: overrun");
        assert!(check::max_extent(&claims, Buf::Lower) <= total, "conv fwd {ctx}: lower overrun");
    }

    // Backward: df/db (and optionally dx) plans for the same shape.
    let mut plans = 1;
    for want_dx in [false, true] {
        let df_grid = plan_tile_grid_with_floor(d.n * d.h, kk, d.co, workers, 1, floor);
        let dx_grid = plan_cols_for_rows_with_floor(
            df_grid.rows_per_tile,
            df_grid.row_tiles,
            d.k * d.k * d.co,
            d.c,
            workers,
            floor,
        );
        let (dag, lower_total) = conv_bwd_dag(d, want_dx, &df_grid, &dx_grid);
        let claims = conv_bwd_claims(d, want_dx, &dag);
        check::verify(&dag, &claims)
            .unwrap_or_else(|v| panic!("conv bwd {ctx} want_dx={want_dx}: {v}"));
        let dx_hi = check::max_extent(&claims, Buf::Out);
        if want_dx {
            assert!(dx_hi <= d.x_len(), "conv bwd {ctx}: dx overrun");
        } else {
            assert_eq!(dx_hi, 0, "conv bwd {ctx}: df-only plan claims dx");
        }
        assert!(
            check::max_extent(&claims, Buf::Lower) <= lower_total,
            "conv bwd {ctx}: lower overrun"
        );
        plans += 1;
    }
    plans
}

/// Every plan the conv planner emits over the shape grid is race-free —
/// including even kernels (per-image dx fallback), kernels wider than the
/// image, ragged output-channel panels (co = 17, 20) and single-pixel
/// feature maps.
#[test]
fn conv_plan_sweep_is_race_free() {
    let mut plans = 0usize;
    for &n in &[1usize, 2, 4] {
        for &(h, w) in &[(1usize, 1usize), (3, 4), (7, 6)] {
            for &c in &[1usize, 3] {
                for &k in &[1usize, 2, 3] {
                    for &co in &[3usize, 8, 17, 20] {
                        for &workers in &[1usize, 4, 8] {
                            for &floor in &[1usize, 64 * 1024] {
                                let d = ConvDims { n, h, w, c, k, co };
                                plans += sweep_conv_shape(&d, workers, floor);
                            }
                        }
                    }
                }
            }
        }
    }
    assert!(plans >= 3000, "sweep shrank to {plans} plans — grid eroded?");
}

/// The paper's Table-2 cases 5–7 regime (2000-neuron FC layers at batch
/// sizes far below the worker count) under the *default* calibrated floor:
/// the planner must actually column-split these, and the split plans must
/// verify clean — ragged final panels included (1250 and 2000 are not
/// multiples of 8, 10 is).
#[test]
fn table2_wide_fc_plans_column_split_and_verify() {
    for &(m, k, n) in &[(4usize, 2000usize, 2000usize), (8, 2000, 2000), (4, 1250, 2000)] {
        let grid = plan_tile_grid(m, k, n, 8, 1);
        assert!(grid.panel_tiles > 1, "m={m} k={k} n={n}: expected a column split, got {grid:?}");
        sweep_dense_shape(m, k, n, 8, 1);
    }
    // Narrow output (n = 10): only two ragged panels exist; whatever the
    // planner picks must still verify.
    sweep_dense_shape(2, 2000, 10, 8, 1);
}
