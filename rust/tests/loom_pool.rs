//! Loom model of the thread pool's sleep/wake/shutdown protocol.
//!
//! Built only under `RUSTFLAGS="--cfg loom"` (the `loom` lane in
//! `.github/workflows/sanitizers.yml`, which appends the loom
//! dev-dependency at job time — it is not listed in Cargo.toml because the
//! offline registry cannot resolve it). Each `loom::model` run exhaustively
//! explores thread interleavings of the condvar park/post handshake, so a
//! lost-wakeup or missed-shutdown bug fails deterministically instead of
//! hanging CI once a month. Pools are kept at 1–2 workers: loom's state
//! space is exponential in thread count (and capped at 4 threads).
#![cfg(loom)]

use bptcnn::util::threadpool::ThreadPool;
use loom::sync::atomic::{AtomicUsize, Ordering};
use loom::sync::Arc;

/// Shared-queue posts into a (possibly parked) pool: every job runs exactly
/// once and `wait_idle` returns — no lost wakeups in any interleaving.
#[test]
fn shared_jobs_all_run_and_wait_idle_returns() {
    loom::model(|| {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..2 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 2);
    });
}

/// Pinned posts (the Algorithm-4.2 dispatch path) wake exactly their
/// worker; both private queues drain under every interleaving.
#[test]
fn pinned_jobs_drain_private_queues() {
    loom::model(|| {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for i in 0..2 {
            let c = Arc::clone(&counter);
            pool.execute_on(i, move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 2);
    });
}

/// Dropping the pool with jobs still queued runs them all, then shuts the
/// worker down and joins it — shutdown can never race a pending job away.
#[test]
fn drop_with_queued_jobs_completes_them_and_joins() {
    loom::model(|| {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(1);
            for _ in 0..2 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // Drop: wait_idle → shutdown flag → notify → join.
        assert_eq!(counter.load(Ordering::SeqCst), 2);
    });
}
