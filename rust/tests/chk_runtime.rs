//! Runtime claim cross-check exercises (`--features chk` only): the
//! `DisjointBuf` accessors registered with a stage guard must admit exactly
//! the accesses the plan declared, reject everything else, survive task
//! panics without poisoning attribution, and pass clean under the real
//! production stages.
#![cfg(feature = "chk")]

use std::panic::{catch_unwind, AssertUnwindSafe};

use bptcnn::inner::check::{self, Buf, Claim, Span};
use bptcnn::inner::{dense_fwd_parallel, execute_dag, panel_count, DisjointBuf, TaskDag, TileGrid};
use bptcnn::nn::ops::{self, PackedB};
use bptcnn::util::threadpool::ThreadPool;

/// Panic payloads from the checker are formatted strings.
fn payload_str(p: Box<dyn std::any::Any + Send>) -> String {
    match p.downcast::<String>() {
        Ok(s) => *s,
        Err(p) => p.downcast::<&'static str>().map(|s| s.to_string()).unwrap_or_default(),
    }
}

#[test]
fn declared_access_passes_and_undeclared_panics() {
    let mut dag: TaskDag<()> = TaskDag::new();
    let t0 = dag.add("t0", 1.0, &[], ());
    let t1 = dag.add("t1", 1.0, &[], ());
    let guard = check::stage_guard(&dag, || {
        vec![
            Claim::write(t0, Buf::Out, Span::interval(0, 4)),
            Claim::write(t1, Buf::Out, Span::interval(4, 4)),
        ]
    });
    let mut data = vec![0.0f32; 8];
    let db = DisjointBuf::new(&mut data).checked(Buf::Out, &guard);
    // Declared write window: admitted.
    check::scoped_task(t0, || {
        // SAFETY: [0, 4) is t0's claimed window; t1 never touches it.
        unsafe { db.slice_mut(0, 4) }.fill(1.0);
    });
    // A write claim licenses reading the same window back.
    check::scoped_task(t1, || {
        // SAFETY: [4, 8) is t1's claimed window.
        assert_eq!(unsafe { db.slice_ref(4, 4) }, &[0.0; 4]);
    });
    // Undeclared window: rejected with task attribution.
    let err = catch_unwind(AssertUnwindSafe(|| {
        check::scoped_task(t0, || {
            // SAFETY: in-bounds window; the claim check panics before any
            // aliasing access can happen.
            let _ = unsafe { db.slice_mut(4, 4) };
        })
    }))
    .unwrap_err();
    let msg = payload_str(err);
    assert!(msg.contains("undeclared Write"), "{msg}");
    assert!(msg.contains("t0"), "{msg}");
    // Outside any task scope (dispatcher preparing buffers): unchecked.
    // SAFETY: no tasks are running; this thread owns the whole buffer.
    unsafe { db.slice_mut(0, 8) }.fill(0.0);
}

#[test]
fn conflicting_plan_is_rejected_at_stage_guard() {
    let mut dag: TaskDag<()> = TaskDag::new();
    let a = dag.add("a", 1.0, &[], ());
    let b = dag.add("b", 1.0, &[], ());
    let err = catch_unwind(AssertUnwindSafe(|| {
        let guard = check::stage_guard(&dag, || {
            vec![
                Claim::write(a, Buf::Out, Span::interval(0, 8)),
                Claim::write(b, Buf::Out, Span::interval(4, 8)),
            ]
        });
        drop(guard); // unreachable: the guard panics on the racy plan
    }))
    .unwrap_err();
    let msg = payload_str(err);
    assert!(msg.contains("unsound stage plan"), "{msg}");
    assert!(msg.contains("write-write"), "{msg}");
}

/// A task panicking mid-tile must not poison claim state: the panic
/// re-raises on the dispatching thread, the worker's task attribution is
/// restored, and a fresh stage on the same pool verifies cleanly.
#[test]
fn task_panic_does_not_poison_claim_checking() {
    let pool = ThreadPool::new(1); // one worker: probes share its thread
    let mut data = vec![0.0f32; 8];
    {
        let mut dag: TaskDag<usize> = TaskDag::new();
        for i in 0..4 {
            dag.add(format!("w{i}"), 1.0, &[], i);
        }
        let guard = check::stage_guard(&dag, || {
            (0..4).map(|i| Claim::write(i, Buf::Out, Span::interval(i * 2, 2))).collect()
        });
        let db = DisjointBuf::new(&mut data).checked(Buf::Out, &guard);
        let err = catch_unwind(AssertUnwindSafe(|| {
            execute_dag(&pool, dag, |_, &i: &usize| {
                // SAFETY: task i exclusively owns [2i, 2i+2).
                unsafe { db.slice_mut(i * 2, 2) }.fill(i as f32);
                if i == 2 {
                    panic!("tile exploded mid-stage");
                }
            })
        }));
        assert!(err.is_err(), "task panic must re-raise on the dispatcher");
    }
    // scoped_task's drop guard restored the worker's attribution …
    pool.execute(|| assert!(check::current_task().is_none(), "stale task id on worker"));
    pool.wait_idle();
    // … and a fresh stage (fresh guard) on the same pool checks clean.
    let mut dag: TaskDag<usize> = TaskDag::new();
    for i in 0..4 {
        dag.add(format!("v{i}"), 1.0, &[], i);
    }
    let guard = check::stage_guard(&dag, || {
        (0..4).map(|i| Claim::write(i, Buf::Out, Span::interval(i * 2, 2))).collect()
    });
    let db = DisjointBuf::new(&mut data).checked(Buf::Out, &guard);
    execute_dag(&pool, dag, |_, &i: &usize| {
        // SAFETY: task i exclusively owns [2i, 2i+2).
        unsafe { db.slice_mut(i * 2, 2) }.fill(-1.0);
    });
    assert_eq!(data, vec![-1.0; 8]);
}

/// Production stage under the cross-check, after an unrelated task panic on
/// the same pool: the column-split dense forward must run every accessor
/// through its claims without a violation and still match the serial path.
#[test]
fn dense_fwd_parallel_checks_clean_after_unrelated_panic() {
    let pool = ThreadPool::new(4);
    let mut dag: TaskDag<()> = TaskDag::new();
    dag.add("boom", 1.0, &[], ());
    let err = catch_unwind(AssertUnwindSafe(|| {
        execute_dag(&pool, dag, |_, _: &()| panic!("boom"));
    }));
    assert!(err.is_err());

    let (m, k, n) = (7usize, 10usize, 19usize); // ragged rows and panels
    let x: Vec<f32> = (0..m * k).map(|i| (i % 13) as f32 * 0.25 - 1.0).collect();
    let w: Vec<f32> = (0..k * n).map(|i| (i % 7) as f32 * 0.5 - 1.5).collect();
    let b: Vec<f32> = (0..n).map(|i| i as f32 * 0.1).collect();
    let packed = PackedB::pack(k, n, &w);
    let mut serial = vec![0.0f32; m * n];
    ops::dense_fwd_packed(m, &x, &packed, &b, &mut serial);
    let panels = panel_count(n);
    let grid = TileGrid {
        rows_per_tile: 2,
        row_tiles: (m + 1) / 2,
        panels_per_tile: 1,
        panel_tiles: panels,
    };
    let mut par = vec![0.0f32; m * n];
    dense_fwd_parallel(&pool, m, &x, &packed, &b, &mut par, false, grid);
    assert_eq!(par, serial);
}
