//! Cross-module integration tests: full training flows over the real
//! in-process cluster, cross-backend parity, experiment smoke runs, and the
//! end-to-end composition the paper's architecture promises.

use std::sync::Arc;

use bptcnn::config::{
    ClusterConfig, NetworkConfig, PartitionStrategy, TrainConfig, UpdateStrategy,
};
use bptcnn::data::Dataset;
use bptcnn::nn::Network;
use bptcnn::outer::worker::LocalTrainer;
use bptcnn::outer::{train_native, NativeTrainer};
use bptcnn::sim::{simulate, SimConfig};

/// Timing-sensitive tests measure wall-clock sleeps; on a single-core runner
/// concurrent tests distort them, so they serialize on this lock. A panicking
/// timing test poisons the mutex; later tests recover the guard instead of
/// cascading unrelated failures.
static TIMING: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn timing_guard() -> std::sync::MutexGuard<'static, ()> {
    TIMING.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

fn quick_tc(update: UpdateStrategy, partition: PartitionStrategy) -> TrainConfig {
    TrainConfig {
        network: NetworkConfig::quickstart(),
        update,
        partition,
        total_samples: 512,
        iterations: 8,
        idpa_batches: 3,
        learning_rate: 0.3,
        seed: 99,
    }
}

/// The whole outer layer learns the synthetic task end-to-end with every
/// strategy combination.
#[test]
fn native_training_learns_under_all_strategies() {
    let cluster = ClusterConfig::heterogeneous(3, 5);
    for update in [UpdateStrategy::Agwu, UpdateStrategy::Sgwu] {
        for partition in [PartitionStrategy::Idpa, PartitionStrategy::Udpa] {
            let tc = quick_tc(update, partition);
            let r = train_native(&tc, &cluster);
            assert!(
                r.final_accuracy > 0.15,
                "{}+{} accuracy {} too low",
                update.name(),
                partition.name(),
                r.final_accuracy
            );
            // Note: the Eq.-16 square error of *softmax* outputs can rise
            // while accuracy improves (confident-but-occasionally-wrong
            // beats uniform in accuracy yet not in MSE), so accuracy above
            // chance is the learning criterion here; monotone-loss checks
            // live in the worker/e2e tests with longer horizons.
            assert!(
                r.final_accuracy > 1.5 * (1.0 / tc.network.num_classes as f64),
                "{}+{} final accuracy {} not above chance",
                update.name(),
                partition.name(),
                r.final_accuracy
            );
        }
    }
}

/// IDPA's allocations follow node speed; UDPA's don't. On a sharply skewed
/// cluster the IDPA run must end better balanced.
#[test]
fn idpa_beats_udpa_on_balance() {
    let _guard = timing_guard();
    let mut cluster = ClusterConfig::homogeneous(3);
    cluster.nodes[0].freq_ghz = 3.2;
    cluster.nodes[2].freq_ghz = 1.1;
    let idpa = train_native(&quick_tc(UpdateStrategy::Sgwu, PartitionStrategy::Idpa), &cluster);
    let udpa = train_native(&quick_tc(UpdateStrategy::Sgwu, PartitionStrategy::Udpa), &cluster);
    assert!(idpa.allocations[0] > idpa.allocations[2], "{:?}", idpa.allocations);
    assert!(udpa.allocations[0].abs_diff(udpa.allocations[2]) <= 1);
    assert!(
        idpa.balance_index > udpa.balance_index,
        "IDPA {} vs UDPA {}",
        idpa.balance_index,
        udpa.balance_index
    );
    assert!(idpa.sync_wait_s < udpa.sync_wait_s);
}

/// The accuracy-weighted SGWU merge (Eq. 7) of identical shards equals each
/// worker's own result: consensus sanity.
#[test]
fn sgwu_consensus_on_identical_shards() {
    let cfg = NetworkConfig::quickstart();
    let ds = Arc::new(Dataset::synthetic(&cfg, 32, 0.2, 77));
    // Two workers over the SAME indices → identical local training.
    let schedule = vec![vec![0..32, 0..32]];
    let workers: Vec<Box<dyn LocalTrainer>> = (0..2)
        .map(|_| Box::new(NativeTrainer::new(&cfg, Arc::clone(&ds), 0.2)) as Box<dyn LocalTrainer>)
        .collect();
    let init = Network::init(&cfg, 5).weights;
    let report = bptcnn::outer::run_sgwu(init.clone(), workers, &schedule, 2, None);

    let mut solo = NativeTrainer::new(&cfg, ds, 0.2);
    solo.add_samples(0..32);
    let mut w = init;
    for _ in 0..2 {
        w = solo.train_epoch(Arc::new(w)).weights;
    }
    assert!(
        report.final_weights.max_abs_diff(&w) < 1e-5,
        "consensus diff {}",
        report.final_weights.max_abs_diff(&w)
    );
}

/// Simulator and real cluster agree on the *direction* of every headline
/// claim at matched (small) scale.
#[test]
fn simulator_agrees_with_real_cluster_directionally() {
    let _guard = timing_guard();
    // Real cluster measurements.
    let mut cluster = ClusterConfig::homogeneous(3);
    cluster.nodes[2].freq_ghz = 1.0;
    cluster.nodes[0].freq_ghz = 3.0;
    let real_sync = train_native(&quick_tc(UpdateStrategy::Sgwu, PartitionStrategy::Udpa), &cluster);
    let real_async = train_native(&quick_tc(UpdateStrategy::Agwu, PartitionStrategy::Udpa), &cluster);
    assert!(real_sync.sync_wait_s > real_async.sync_wait_s);

    // Same scenario simulated.
    let base = SimConfig {
        network: NetworkConfig::quickstart(),
        cluster,
        update: UpdateStrategy::Sgwu,
        partition: PartitionStrategy::Udpa,
        samples: 512,
        iterations: 8,
        idpa_batches: 3,
        threads_per_node: 8,
        seed: 1,
    };
    let sim_sync = simulate(&base);
    let sim_async = simulate(&SimConfig { update: UpdateStrategy::Agwu, ..base.clone() });
    assert!(sim_sync.sync_wait_s > sim_async.sync_wait_s);
    assert!(sim_async.total_s <= sim_sync.total_s);
}

/// Experiment regenerators run end-to-end in quick mode (simulated figures).
#[test]
fn experiment_smoke_fig12_fig14_fig15() {
    for id in ["fig12", "fig13", "fig14", "fig15"] {
        let out = bptcnn::experiments::run(id, true).unwrap();
        assert!(out.contains("Fig."), "{id} produced no figure output");
        assert!(out.contains("BPT-CNN") || out.contains("AGWU"), "{id} missing rows");
    }
}

/// Full three-layer composition: artifacts → PJRT → distributed AGWU+IDPA
/// training (skips when artifacts are absent; compiled only with the real
/// PJRT backend — the default stub build would fail it even with artifacts).
#[cfg(feature = "xla-pjrt")]
#[test]
fn xla_distributed_training_end_to_end() {
    use bptcnn::runtime::{find_model_dir, XlaService, XlaTrainer};
    let Some(dir) = find_model_dir("quickstart") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let service = XlaService::start(&dir).unwrap();
    let network = service.handle().manifest.config.clone();
    let cluster = ClusterConfig::heterogeneous(2, 3);
    let tc = TrainConfig {
        network: network.clone(),
        update: UpdateStrategy::Agwu,
        partition: PartitionStrategy::Idpa,
        total_samples: 256,
        iterations: 4,
        idpa_batches: 2,
        learning_rate: 0.3,
        seed: 7,
    };
    let ds = Arc::new(Dataset::synthetic(&network, tc.total_samples, 0.3, tc.seed));
    let (schedule, _, iters) = bptcnn::outer::build_schedule(&tc, &cluster);
    let workers: Vec<Box<dyn LocalTrainer>> = (0..2)
        .map(|_| {
            Box::new(XlaTrainer::new(service.handle(), Arc::clone(&ds), 0.3))
                as Box<dyn LocalTrainer>
        })
        .collect();
    let init = service.handle().init_weights(7).unwrap();
    let report = bptcnn::outer::run_agwu(init, workers, &schedule, iters, None);
    assert_eq!(report.versions.len(), 2 * iters);
    let first = report.versions.first().unwrap().local_loss;
    let last = report.versions.last().unwrap().local_loss;
    assert!(last < first, "XLA distributed training did not learn: {first} → {last}");
}

/// ThreadPool::wait_idle under mixed `execute` / `execute_on` load from
/// several producer threads: every job runs exactly once, wait_idle returns
/// only after all of them, and repeated rounds don't wedge the pool.
#[test]
fn threadpool_wait_idle_stress_mixed_load() {
    use bptcnn::util::threadpool::ThreadPool;
    use std::sync::atomic::{AtomicUsize, Ordering};

    let pool = Arc::new(ThreadPool::new(4));
    for round in 0..5 {
        let shared_jobs = 150;
        let pinned_jobs = 150;
        let counter = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|scope| {
            for producer in 0..3 {
                let pool = Arc::clone(&pool);
                let counter = Arc::clone(&counter);
                scope.spawn(move || {
                    for i in 0..shared_jobs / 3 {
                        let c = Arc::clone(&counter);
                        pool.execute(move || {
                            if i % 17 == 0 {
                                std::thread::sleep(std::time::Duration::from_micros(50));
                            }
                            c.fetch_add(1, Ordering::SeqCst);
                        });
                        let c = Arc::clone(&counter);
                        pool.execute_on((producer + i) % pool.size(), move || {
                            c.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                });
            }
        });
        pool.wait_idle();
        assert_eq!(
            counter.load(Ordering::SeqCst),
            shared_jobs / 3 * 3 + pinned_jobs / 3 * 3,
            "round {round}: jobs lost or duplicated"
        );
    }
}

/// Eq. 11 holds on the real cluster: 2·m·K weight-set transfers.
#[test]
fn communication_matches_eq11_on_real_cluster() {
    let cluster = ClusterConfig::homogeneous(3);
    let tc = quick_tc(UpdateStrategy::Agwu, PartitionStrategy::Udpa);
    let r = train_native(&tc, &cluster);
    let expected_transfers = 2 * 3 * tc.iterations;
    assert_eq!(r.cluster.comm.fetches + r.cluster.comm.submits, expected_transfers);
    let expected_mb = (expected_transfers * tc.network.weight_bytes()) as f64 / (1024.0 * 1024.0);
    assert!((r.comm_mb - expected_mb).abs() < 1e-9);
}

/// PR6 tentpole: three real worker endpoints drive AGWU against the
/// standalone param-server service over loopback TCP. The run must produce
/// the same version/comm ledger shape as the in-process cluster (Eq. 11:
/// 2·m·K logical transfers), move real wire bytes, learn, and land within a
/// loose tolerance of an in-process AGWU run with identical trainers (AGWU
/// interleaving is nondeterministic in both deployments, so exact equality
/// is not expected here — see the SGWU test below for bitwise parity).
#[test]
fn tcp_loopback_agwu_three_workers_matches_inprocess() {
    use bptcnn::outer::{
        drive_worker, run_agwu, schedule_columns, serve, ServeOptions, Staleness, SubmitMode,
        TcpTransport,
    };
    use std::net::TcpListener;

    let cfg = NetworkConfig::quickstart();
    let ds = Arc::new(Dataset::synthetic(&cfg, 192, 0.3, 11));
    let init = Network::init(&cfg, 11).weights;
    let schedule = vec![vec![0..64, 64..128, 128..192]];
    let (m, iterations) = (3usize, 3usize);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = ServeOptions { nodes: m, update: UpdateStrategy::Agwu, ..ServeOptions::default() };
    let server = {
        let init = init.clone();
        std::thread::spawn(move || serve(listener, init, opts))
    };
    let handles: Vec<_> = schedule_columns(&schedule, m)
        .into_iter()
        .enumerate()
        .map(|(node, column)| {
            let (addr, ds, cfg) = (addr.clone(), Arc::clone(&ds), cfg.clone());
            std::thread::spawn(move || {
                let mut t = TcpTransport::connect(&addr, node).unwrap();
                let mut trainer = NativeTrainer::new(&cfg, ds, 0.2);
                drive_worker(
                    &mut t,
                    &mut trainer,
                    &column,
                    iterations,
                    SubmitMode::Agwu,
                    Staleness(0),
                    false,
                )
                .unwrap()
            })
        })
        .collect();
    let summaries: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let report = server.join().unwrap().unwrap();

    assert_eq!(report.versions.len(), m * iterations);
    assert_eq!(report.comm.fetches, m * iterations);
    assert_eq!(report.comm.submits, m * iterations);
    assert_eq!(report.comm.bytes, (2 * m * iterations * cfg.weight_bytes()) as u64);
    assert!(report.comm.wire_bytes > report.comm.bytes, "frames add protocol overhead");
    assert!(report.comm.comm_wall_s() > 0.0);
    for s in &summaries {
        assert_eq!(s.iterations, iterations);
        assert!(s.stats.wire_bytes > 0, "endpoint moved no bytes");
        assert!(s.busy_s > 0.0);
    }
    let first = report.versions.first().unwrap().local_loss;
    let last = report.versions.last().unwrap().local_loss;
    assert!(last < first, "TCP AGWU did not learn: {first} -> {last}");

    let workers: Vec<Box<dyn LocalTrainer>> = (0..m)
        .map(|_| Box::new(NativeTrainer::new(&cfg, Arc::clone(&ds), 0.2)) as Box<dyn LocalTrainer>)
        .collect();
    let inproc = run_agwu(init, workers, &schedule, iterations, None);
    assert_eq!(inproc.versions.len(), report.versions.len());
    let diff = report.final_weights.max_abs_diff(&inproc.final_weights);
    assert!(diff < 0.5, "TCP vs in-process AGWU diverged: max|Δw| = {diff}");
}

/// PR6 tentpole: SGWU is deterministic — submissions buffer at the barrier
/// and apply in node order regardless of arrival order — so a 2-worker SGWU
/// run over loopback TCP must be *bit-identical* to the in-process cluster
/// from the same init, dataset and schedule. This is the strongest parity
/// guarantee the transport refactor makes.
#[test]
fn tcp_loopback_sgwu_bitwise_matches_inprocess() {
    use bptcnn::outer::{
        drive_worker, run_sgwu, schedule_columns, serve, ServeOptions, Staleness, SubmitMode,
        TcpTransport,
    };
    use std::net::TcpListener;

    let cfg = NetworkConfig::quickstart();
    let ds = Arc::new(Dataset::synthetic(&cfg, 144, 0.3, 23));
    let init = Network::init(&cfg, 23).weights;
    // Two allocation batches → exercises incremental add_samples on both paths.
    let schedule = vec![vec![0..48, 48..96], vec![96..120, 120..144]];
    let (m, iterations) = (2usize, 2usize);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = ServeOptions { nodes: m, update: UpdateStrategy::Sgwu, ..ServeOptions::default() };
    let server = {
        let init = init.clone();
        std::thread::spawn(move || serve(listener, init, opts))
    };
    let handles: Vec<_> = schedule_columns(&schedule, m)
        .into_iter()
        .enumerate()
        .map(|(node, column)| {
            let (addr, ds, cfg) = (addr.clone(), Arc::clone(&ds), cfg.clone());
            std::thread::spawn(move || {
                let mut t = TcpTransport::connect(&addr, node).unwrap();
                let mut trainer = NativeTrainer::new(&cfg, ds, 0.25);
                drive_worker(
                    &mut t,
                    &mut trainer,
                    &column,
                    iterations,
                    SubmitMode::Sgwu,
                    Staleness(0),
                    false,
                )
                .unwrap()
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let report = server.join().unwrap().unwrap();

    let workers: Vec<Box<dyn LocalTrainer>> = (0..m)
        .map(|_| Box::new(NativeTrainer::new(&cfg, Arc::clone(&ds), 0.25)) as Box<dyn LocalTrainer>)
        .collect();
    let inproc = run_sgwu(init, workers, &schedule, iterations, None);

    // One installed version per round, flagged as the all-nodes merge.
    assert_eq!(report.versions.len(), iterations);
    assert!(report.versions.iter().all(|v| v.node == usize::MAX));
    assert_eq!(report.comm.fetches, inproc.comm.fetches);
    assert_eq!(report.comm.submits, inproc.comm.submits);
    assert_eq!(report.comm.bytes, inproc.comm.bytes);
    let diff = report.final_weights.max_abs_diff(&inproc.final_weights);
    assert_eq!(diff, 0.0, "SGWU over TCP must be bit-identical, got max|Δw| = {diff}");
}

/// PR8 tentpole: the pipelined worker loop (comm on a background thread,
/// snapshots allowed to lag ≤ 1 version) drives the same 3-worker AGWU
/// deployment over loopback TCP and must clear the same gates as the
/// serialized run: full Eq. 11 ledger, learning in the right direction, and
/// a final weight set within the serialized test's tolerance of an
/// in-process AGWU run — staleness changes interleaving, not convergence.
#[test]
fn tcp_loopback_pipelined_agwu_staleness1_matches_gates() {
    use bptcnn::outer::{
        drive_worker, run_agwu, schedule_columns, serve, ServeOptions, Staleness, SubmitMode,
        TcpTransport,
    };
    use std::net::TcpListener;

    let cfg = NetworkConfig::quickstart();
    let ds = Arc::new(Dataset::synthetic(&cfg, 192, 0.3, 11));
    let init = Network::init(&cfg, 11).weights;
    let schedule = vec![vec![0..64, 64..128, 128..192]];
    let (m, iterations) = (3usize, 3usize);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = ServeOptions { nodes: m, update: UpdateStrategy::Agwu, ..ServeOptions::default() };
    let server = {
        let init = init.clone();
        std::thread::spawn(move || serve(listener, init, opts))
    };
    let handles: Vec<_> = schedule_columns(&schedule, m)
        .into_iter()
        .enumerate()
        .map(|(node, column)| {
            let (addr, ds, cfg) = (addr.clone(), Arc::clone(&ds), cfg.clone());
            std::thread::spawn(move || {
                let mut t = TcpTransport::connect(&addr, node).unwrap();
                let mut trainer = NativeTrainer::new(&cfg, ds, 0.2);
                drive_worker(
                    &mut t,
                    &mut trainer,
                    &column,
                    iterations,
                    SubmitMode::Agwu,
                    Staleness(1),
                    false,
                )
                .unwrap()
            })
        })
        .collect();
    let summaries: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let report = server.join().unwrap().unwrap();

    // Same Eq. 11 ledger as the serialized deployment: the pipeline reorders
    // transfers, it does not add or drop any.
    assert_eq!(report.versions.len(), m * iterations);
    assert_eq!(report.comm.submits, m * iterations);
    assert!(report.comm.fetches >= m * iterations, "prefetches can only add fetches");
    for s in &summaries {
        assert_eq!(s.iterations, iterations);
        assert_eq!(s.ack_log.len(), iterations, "one ack per submitted epoch");
        assert!(s.max_staleness <= 1, "staleness bound violated: {}", s.max_staleness);
        assert!(s.stats.connect_wall_s > 0.0, "TCP connect wall not attributed");
        assert!(s.stats.wire_bytes > 0, "endpoint moved no bytes");
    }
    assert!(
        summaries.iter().any(|s| s.stats.max_inflight >= 1),
        "no worker ever had a request in flight — pipeline never overlapped"
    );
    let first = report.versions.first().unwrap().local_loss;
    let last = report.versions.last().unwrap().local_loss;
    assert!(last < first, "pipelined TCP AGWU did not learn: {first} -> {last}");

    let workers: Vec<Box<dyn LocalTrainer>> = (0..m)
        .map(|_| Box::new(NativeTrainer::new(&cfg, Arc::clone(&ds), 0.2)) as Box<dyn LocalTrainer>)
        .collect();
    let inproc = run_agwu(init, workers, &schedule, iterations, None);
    let diff = report.final_weights.max_abs_diff(&inproc.final_weights);
    assert!(diff < 0.5, "pipelined TCP vs in-process AGWU diverged: max|Δw| = {diff}");
}

/// PR9 tentpole: kill-and-recover. Three worker slots, AGWU, `--on-failure
/// continue`. The victim registers, fetches once, and dies without ever
/// submitting (its dropped socket is the crash). The server must declare it
/// dead, re-allocate both of its unconsumed IDPA batches to the survivors in
/// proportion to measured throughput (all-zero here → equal split), deliver
/// them piggybacked on the survivors' next fetch, and complete the run with
/// the loss still improving.
#[test]
fn tcp_agwu_kill_and_recover_survivors_absorb_dead_nodes_batches() {
    use bptcnn::config::OnFailure;
    use bptcnn::outer::{
        drive_worker, schedule_columns, serve, ServeOptions, Staleness, SubmitMode, TcpTransport,
        Transport,
    };
    use std::net::TcpListener;

    let cfg = NetworkConfig::quickstart();
    let ds = Arc::new(Dataset::synthetic(&cfg, 240, 0.3, 31));
    let init = Network::init(&cfg, 31).weights;
    // Two allocation batches per node (rows × nodes); node 2 owns 160..240.
    let schedule = vec![
        vec![0..40, 80..120, 160..200],
        vec![40..80, 120..160, 200..240],
    ];
    let (m, iterations) = (3usize, 4usize);
    let columns = schedule_columns(&schedule, m);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = ServeOptions {
        nodes: m,
        update: UpdateStrategy::Agwu,
        on_failure: OnFailure::Continue,
        schedule: Some(columns.clone()),
        ..ServeOptions::default()
    };
    let server = {
        let init = init.clone();
        std::thread::spawn(move || serve(listener, init, opts))
    };

    // The victim: node 2 registers and fetches, then its socket drops with
    // no Done — a kill -9 as the server sees it.
    {
        let mut victim = TcpTransport::connect(&addr, 2).unwrap();
        victim.fetch_global().unwrap();
    }
    // Let the server observe the EOF and re-allocate before the survivors
    // register, so their very first Global reply carries the extras.
    std::thread::sleep(std::time::Duration::from_millis(200));

    let handles: Vec<_> = columns
        .iter()
        .take(2)
        .cloned()
        .enumerate()
        .map(|(node, column)| {
            let (addr, ds, cfg) = (addr.clone(), Arc::clone(&ds), cfg.clone());
            std::thread::spawn(move || {
                let mut t = TcpTransport::connect(&addr, node).unwrap();
                let mut trainer = NativeTrainer::new(&cfg, ds, 0.2);
                let summary = drive_worker(
                    &mut t,
                    &mut trainer,
                    &column,
                    iterations,
                    SubmitMode::Agwu,
                    Staleness(0),
                    false,
                )
                .unwrap();
                (summary, trainer.sample_count())
            })
        })
        .collect();
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let report = server.join().unwrap().expect("run must survive the crash");

    // The dead node's two batches (80 samples) moved, none were lost: the
    // two survivors' shards now cover the full 240-sample dataset.
    assert_eq!(report.fault.reallocated_batches, 2);
    assert_eq!(report.fault.reallocated_samples, 80);
    assert_eq!(report.fault.leases_expired, 0, "death came from EOF, not a lease");
    let counts: Vec<usize> = results.iter().map(|(_, n)| *n).collect();
    assert_eq!(counts.iter().sum::<usize>(), 240, "samples lost or duplicated: {counts:?}");
    assert!(counts.iter().all(|&n| n > 80), "re-allocation not spread: {counts:?}");

    // Only the survivors contributed versions, and the run still learned.
    assert_eq!(report.versions.len(), 2 * iterations);
    let first = report.versions.first().unwrap().local_loss;
    let last = report.versions.last().unwrap().local_loss;
    assert!(last < first, "run did not keep learning after the crash: {first} -> {last}");
}

/// PR9 acceptance gate: `--resume` from a mid-run checkpoint reproduces the
/// uninterrupted run's final weights *bit-identically*. Single-node AGWU
/// with a one-batch shard is fully deterministic, so 2 epochs + (resume
/// from the v2 checkpoint) + 2 epochs must equal 4 straight epochs.
#[test]
fn checkpoint_resume_reproduces_bit_identical_weights() {
    use bptcnn::outer::{
        drive_worker, read_checkpoint, serve, ServeOptions, Staleness, SubmitMode, TcpTransport,
    };
    use bptcnn::tensor::WeightSet;
    use std::net::TcpListener;
    use std::path::PathBuf;

    let cfg = NetworkConfig::quickstart();
    let ds = Arc::new(Dataset::synthetic(&cfg, 96, 0.3, 41));
    let init = Network::init(&cfg, 41).weights;
    let column = vec![0..96]; // one batch: every epoch trains the same shard

    let run = |init: WeightSet,
               iters: usize,
               dir: Option<PathBuf>,
               init_version: usize,
               resumed: bool| {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let opts = ServeOptions {
            nodes: 1,
            update: UpdateStrategy::Agwu,
            checkpoint_dir: dir,
            checkpoint_every: 1,
            init_version,
            resumed,
            ..ServeOptions::default()
        };
        let server = std::thread::spawn(move || serve(listener, init, opts));
        let mut t = TcpTransport::connect(&addr, 0).unwrap();
        let mut trainer = NativeTrainer::new(&cfg, Arc::clone(&ds), 0.2);
        drive_worker(
            &mut t,
            &mut trainer,
            &column,
            iters,
            SubmitMode::Agwu,
            Staleness(0),
            false,
        )
        .unwrap();
        server.join().unwrap().unwrap()
    };

    let full = run(init.clone(), 4, None, 0, false);

    let dir = std::env::temp_dir().join(format!("bptcnn-ckpt-resume-{}", std::process::id()));
    let half = run(init, 2, Some(dir.clone()), 0, false);
    assert!(half.fault.checkpoints_written >= 2, "cadence 1 must checkpoint every version");

    let (version, restored) = read_checkpoint(&dir).unwrap();
    assert_eq!(version, 2);
    assert_eq!(
        restored.max_abs_diff(&half.final_weights),
        0.0,
        "latest checkpoint must capture the v2 state exactly"
    );

    let resumed = run(restored, 2, None, 2, true);
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(resumed.fault.checkpoints_loaded, 1);
    assert_eq!(
        resumed.versions.last().unwrap().version,
        full.versions.last().unwrap().version,
        "resumed run must continue the version sequence, not restart it"
    );
    let diff = resumed.final_weights.max_abs_diff(&full.final_weights);
    assert_eq!(diff, 0.0, "resume must be bit-identical to the unbroken run, got max|Δw| = {diff}");
}

/// PR9 satellite: a malformed frame is answered with a typed wire `Error`
/// the peer can actually read — the server holds its read side open until
/// the frame is collected (naively closing right after the write can turn
/// into a TCP RST that destroys it) — and the run aborts as a protocol
/// violation.
#[test]
fn tcp_malformed_frame_gets_typed_error_reply_and_aborts_run() {
    use bptcnn::outer::wire::{crc32, read_msg, Msg};
    use bptcnn::outer::{serve, ServeOptions};
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let init = Network::init(&NetworkConfig::quickstart(), 3).weights;
    let server =
        std::thread::spawn(move || serve(listener, init, ServeOptions::default()));

    let mut s = TcpStream::connect(addr).unwrap();
    // A well-formed frame (header + valid CRC trailer) carrying an unknown
    // tag where Hello is expected: the decoder must get past the integrity
    // check and reject the *content* without reading further.
    s.write_all(&1u32.to_le_bytes()).unwrap();
    s.write_all(&[0xEE]).unwrap();
    s.write_all(&crc32(&[0xEE]).to_le_bytes()).unwrap();
    s.flush().unwrap();

    let (msg, _) = read_msg(&mut s).unwrap();
    match msg {
        Msg::Error { msg } => {
            assert!(msg.contains("bad hello"), "unexpected error text: {msg}")
        }
        other => panic!("expected a typed Error frame, got {other:?}"),
    }
    drop(s);

    let err = server.join().unwrap().expect_err("protocol violation must fail the run");
    assert!(format!("{err:#}").contains("bad hello"), "{err:#}");
}

/// PR9 satellite: the evicted-base straggler fallback (history window cap
/// `2m+2`) under the *pipelined* worker loop. A gated straggler holds its
/// v0 snapshot while the other node installs 12 versions; its eventual
/// submit's base has left the window, the server falls back to the oldest
/// retained version, counts it, and the run still completes.
#[test]
fn pipelined_straggler_takes_evicted_base_fallback() {
    use bptcnn::outer::{
        drive_worker, EpochOutcome, InProcTransport, ParamServer, Staleness, SubmitMode,
        SubmitMeta, Transport,
    };
    use bptcnn::tensor::WeightSet;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Mutex;

    /// Signals `started` when its first epoch begins, then blocks until
    /// `go` — freezing the straggler at a v0 base for as long as the test
    /// needs the fast node to run ahead.
    struct GatedTrainer {
        started: Arc<AtomicBool>,
        go: Arc<AtomicBool>,
        samples: usize,
    }
    impl LocalTrainer for GatedTrainer {
        fn train_epoch(&mut self, start: Arc<WeightSet>) -> EpochOutcome {
            self.started.store(true, Ordering::Release);
            while !self.go.load(Ordering::Acquire) {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            let mut w = (*start).clone();
            w.tensors_mut()[0].data_mut()[0] += 0.01;
            EpochOutcome {
                weights: w,
                loss: 1.0,
                accuracy: 0.5,
                samples: self.samples.max(1),
                compute_s: 0.0,
            }
        }
        fn add_samples(&mut self, range: std::ops::Range<usize>) {
            self.samples += range.len();
        }
        fn sample_count(&self) -> usize {
            self.samples
        }
    }

    let cfg = NetworkConfig::quickstart();
    let init = Network::init(&cfg, 51).weights;
    let ps = Arc::new(Mutex::new(ParamServer::new(init, 2)));
    let started = Arc::new(AtomicBool::new(false));
    let go = Arc::new(AtomicBool::new(false));

    let straggler = {
        let ps = Arc::clone(&ps);
        let (started, go) = (Arc::clone(&started), Arc::clone(&go));
        std::thread::spawn(move || {
            let mut t = InProcTransport::new(ps, 0);
            let mut trainer = GatedTrainer { started, go, samples: 8 };
            drive_worker(
                &mut t,
                &mut trainer,
                &[0..8],
                2,
                SubmitMode::Agwu,
                Staleness(1),
                false,
            )
            .unwrap()
        })
    };

    // Wait until the straggler holds its v0 snapshot, then install 12
    // versions from the fast node — more than the 2m+2 = 6 the history
    // window retains, guaranteeing v0 is gone.
    while !started.load(Ordering::Acquire) {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    let mut fast = InProcTransport::new(Arc::clone(&ps), 1);
    for _ in 0..12 {
        let (w, base) = fast.fetch_global().unwrap();
        let local = (*w).clone();
        let meta = SubmitMeta {
            mode: SubmitMode::Agwu,
            base,
            accuracy: 0.5,
            loss: 1.0,
            want_snapshot: false,
        };
        fast.submit(local, &meta).unwrap();
    }
    go.store(true, Ordering::Release);

    let summary = straggler.join().unwrap();
    assert_eq!(summary.iterations, 2);
    assert!(summary.max_staleness <= 1, "pipeline bound violated: {}", summary.max_staleness);
    let fallbacks = ps.lock().unwrap().comm.evicted_base_fallbacks;
    assert!(
        fallbacks >= 1,
        "straggler's v0 base should have been evicted and counted, got {fallbacks}"
    );
}

// ---------------------------------------------------------------------------
// PR10: process-level high-availability tests. These spawn the real `bptcnn`
// binary (via CARGO_BIN_EXE) so the kill is a genuine SIGKILL delivered to a
// separate OS process — not a simulated socket drop — and the graceful-
// shutdown path is exercised by a real SIGTERM.
// ---------------------------------------------------------------------------

/// Spawn the compiled `bptcnn` binary with both output streams piped.
fn spawn_bptcnn(args: &[&str]) -> std::process::Child {
    std::process::Command::new(env!("CARGO_BIN_EXE_bptcnn"))
        .args(args)
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("spawn bptcnn")
}

/// Read a param-server's stdout until it announces its bound address
/// ("... listening on {addr} ..."), returning the address. The servers bind
/// 127.0.0.1:0, so this is how tests learn the OS-assigned port.
fn read_listen_addr(out: &mut impl std::io::BufRead) -> String {
    let mut line = String::new();
    loop {
        line.clear();
        let n = out.read_line(&mut line).expect("read server stdout");
        assert!(n > 0, "server exited before announcing its address");
        if let Some(idx) = line.find("listening on ") {
            let rest = &line[idx + "listening on ".len()..];
            return rest.split_whitespace().next().unwrap().to_string();
        }
    }
}

/// Installed versions from `--verbose` server stderr lines
/// ("param-server: v{n} node {i} loss ..."), in print order.
fn install_versions(log: &str) -> Vec<u64> {
    log.lines()
        .filter_map(|l| l.strip_prefix("param-server: v"))
        .filter_map(|rest| {
            let digits: String = rest.chars().take_while(char::is_ascii_digit).collect();
            digits.parse().ok()
        })
        .collect()
}

/// The run of ASCII digits in `line` directly after `marker`.
fn digits_after(line: &str, marker: &str) -> String {
    let at = line.find(marker).expect("marker present") + marker.len();
    line[at..].chars().take_while(char::is_ascii_digit).collect()
}

/// PR10 acceptance gate: SIGKILL the *primary param-server* mid-run. One
/// primary (replicating to a warm standby, `--repl-ack standby`) + one
/// standby + three throttled AGWU workers, all real processes over loopback
/// TCP. After the kill the standby's replication lease expires, it promotes
/// itself at a bumped cluster epoch, and every worker fails over via its
/// ordered `--servers` list. The run must complete: all workers exit 0 and
/// report ≥ 1 failover, the standby exits 0 under `--expect-learning` with
/// the loss falling, the version sequence continues strictly from the
/// replicated state (no restart, no gap), and no batches were re-allocated
/// (every worker survived with its own shard — sample conservation is
/// structural).
#[test]
fn process_kill_primary_standby_promotes_and_workers_fail_over() {
    use std::io::{BufRead as _, Read as _};

    let _guard = timing_guard();
    let common = [
        "--network",
        "quickstart",
        "--update",
        "agwu",
        "--nodes",
        "3",
        "--seed",
        "42",
        "--partition",
        "idpa",
        "--samples",
        "510",
        "--iterations",
        "6",
        "--batches",
        "2",
    ];

    let mut standby_args: Vec<&str> = vec![
        "param-server",
        "--listen",
        "127.0.0.1:0",
        "--role",
        "standby",
        "--repl-lease-ms",
        "1200",
        "--claim-deadline-ms",
        "30000",
        "--lease-ms",
        "10000",
        "--on-failure",
        "continue",
        "--expect-learning",
        "--verbose",
    ];
    standby_args.extend_from_slice(&common);
    let mut standby = spawn_bptcnn(&standby_args);
    let mut standby_out = std::io::BufReader::new(standby.stdout.take().unwrap());
    let standby_addr = read_listen_addr(&mut standby_out);

    let mut primary_args: Vec<&str> = vec![
        "param-server",
        "--listen",
        "127.0.0.1:0",
        "--standby",
        &standby_addr,
        "--repl-ack",
        "standby",
        "--lease-ms",
        "1500",
        "--on-failure",
        "continue",
        "--verbose",
    ];
    primary_args.extend_from_slice(&common);
    let mut primary = spawn_bptcnn(&primary_args);
    let mut primary_out = std::io::BufReader::new(primary.stdout.take().unwrap());
    let primary_addr = read_listen_addr(&mut primary_out);
    let mut primary_err = std::io::BufReader::new(primary.stderr.take().unwrap());

    // Every worker is latency-throttled (~0.6 s per iteration), so all three
    // are mid-run when the kill lands and every one of them must fail over.
    let servers = format!("{primary_addr},{standby_addr}");
    let node_ids: Vec<String> = (0..3).map(|n| n.to_string()).collect();
    let workers: Vec<_> = node_ids
        .iter()
        .map(|node| {
            let mut args: Vec<&str> = vec![
                "worker",
                "--servers",
                &servers,
                "--node",
                node,
                "--lr",
                "0.2",
                "--bandwidth-mbs",
                "1000",
                "--latency-ms",
                "300",
                "--retries",
                "12",
                "--retry-backoff-ms",
                "100",
                "--io-timeout-ms",
                "5000",
            ];
            args.extend_from_slice(&common);
            spawn_bptcnn(&args)
        })
        .collect();

    // Kill only once the run is demonstrably in flight: three committed
    // (and, under --repl-ack standby, replicated) installs on the primary.
    let mut primary_log = String::new();
    let mut installs_seen = 0;
    let mut line = String::new();
    while installs_seen < 3 {
        line.clear();
        let n = primary_err.read_line(&mut line).expect("read primary stderr");
        assert!(n > 0, "primary exited before three installs:\n{primary_log}");
        if !install_versions(&line).is_empty() {
            installs_seen += 1;
        }
        primary_log.push_str(&line);
    }
    primary.kill().expect("SIGKILL the primary");
    primary.wait().unwrap();
    primary_err.read_to_string(&mut primary_log).unwrap();

    let worker_outs: Vec<_> = workers
        .into_iter()
        .map(|w| w.wait_with_output().expect("wait worker"))
        .collect();
    let standby_status = standby.wait().expect("wait standby");
    let mut standby_log = String::new();
    standby_out.read_to_string(&mut standby_log).unwrap();
    let mut standby_err = String::new();
    standby.stderr.take().unwrap().read_to_string(&mut standby_err).unwrap();
    let context = format!(
        "--- primary stderr ---\n{primary_log}\n--- standby stdout ---\n{standby_log}\n\
         --- standby stderr ---\n{standby_err}"
    );

    // Every worker failed over to the standby and still finished its shard.
    for (node, out) in worker_outs.iter().enumerate() {
        let stdout = String::from_utf8_lossy(&out.stdout);
        assert!(out.status.success(), "worker {node} failed:\n{stdout}\n{context}");
        assert!(stdout.contains(&format!("worker {node} done:")), "{stdout}");
        let fline = stdout
            .lines()
            .find(|l| l.contains("fault recovery:"))
            .unwrap_or_else(|| panic!("worker {node} printed no fault ledger:\n{stdout}"));
        let failovers: u64 = fline
            .rsplit('|')
            .next()
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(failovers >= 1, "worker {node} never failed over: {fline}");
    }

    // The standby promoted and completed the run with the loss falling.
    assert!(standby_status.success(), "standby failed:\n{context}");
    let promote = standby_err
        .lines()
        .find(|l| l.contains("standby promoting to primary at cluster epoch"))
        .unwrap_or_else(|| panic!("standby never promoted:\n{context}"));
    let repl_v: u64 = digits_after(promote, "(v").parse().unwrap();

    // Version sequence is strictly monotone across the promotion: the
    // primary's installs increase, the promoted standby's installs increase,
    // and the standby's first install continues directly from the state it
    // replicated (which can trail the primary's last *printed* install by
    // the in-flight window, but never precedes an acked one).
    let primary_installs = install_versions(&primary_log);
    assert!(primary_installs.len() >= 3, "{context}");
    assert!(primary_installs.windows(2).all(|w| w[1] > w[0]), "{primary_installs:?}");
    let standby_installs = install_versions(&standby_err);
    assert!(!standby_installs.is_empty(), "promoted standby installed nothing:\n{context}");
    assert!(standby_installs.windows(2).all(|w| w[1] > w[0]), "{standby_installs:?}");
    assert_eq!(
        standby_installs[0],
        repl_v + 1,
        "promotion must continue the replicated version sequence:\n{context}"
    );
    assert!(repl_v <= *primary_installs.last().unwrap(), "{context}");
    // 3 nodes × 6 iterations: every scheduled epoch landed (a submit caught
    // in the failover window may be re-installed, so ≥, not ==).
    assert!(*standby_installs.last().unwrap() >= 18, "{context}");

    let loss_line = standby_log
        .lines()
        .find(|l| l.starts_with("local loss first"))
        .unwrap_or_else(|| panic!("no loss summary:\n{context}"));
    let losses: Vec<f64> = loss_line.split_whitespace().filter_map(|t| t.parse().ok()).collect();
    assert!(losses.len() == 2 && losses[1] < losses[0], "no learning: {loss_line}");

    // Samples conserved the strong way: nobody was declared dead, so no
    // batches moved and each worker trained exactly its own allocation. The
    // promotion itself is the single accounted failover.
    let ledger = standby_log
        .lines()
        .find(|l| l.contains("fault recovery:"))
        .unwrap_or_else(|| panic!("no server fault ledger:\n{context}"));
    assert!(ledger.contains("0 batches (0 samples) re-allocated"), "{ledger}");
    let failovers: u64 =
        ledger.split('|').nth(1).unwrap().split_whitespace().next().unwrap().parse().unwrap();
    assert!(failovers >= 1, "promotion not accounted as a failover: {ledger}");
}

/// PR10 satellite: SIGTERM mid-run is a graceful shutdown, not a crash. A
/// real param-server process with a checkpoint dir takes a SIGTERM while a
/// worker is mid-iteration: it must stop accepting, drain the in-flight
/// submit, write a final checkpoint at exactly the drained version, print
/// the graceful-shutdown line, and exit 0.
#[test]
fn process_sigterm_drains_and_writes_final_checkpoint() {
    use std::io::{BufRead as _, Read as _};

    let _guard = timing_guard();
    let dir = std::env::temp_dir().join(format!("bptcnn-sigterm-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let common = [
        "--network",
        "quickstart",
        "--update",
        "agwu",
        "--nodes",
        "1",
        "--seed",
        "42",
        "--partition",
        "idpa",
        "--samples",
        "96",
        "--iterations",
        "8",
        "--batches",
        "1",
    ];
    let mut server_args: Vec<&str> = vec![
        "param-server",
        "--listen",
        "127.0.0.1:0",
        "--checkpoint-dir",
        dir.to_str().unwrap(),
        "--verbose",
    ];
    server_args.extend_from_slice(&common);
    let mut server = spawn_bptcnn(&server_args);
    let mut server_out = std::io::BufReader::new(server.stdout.take().unwrap());
    let addr = read_listen_addr(&mut server_out);
    let mut server_err = std::io::BufReader::new(server.stderr.take().unwrap());

    let mut worker_args: Vec<&str> = vec![
        "worker",
        "--connect",
        &addr,
        "--node",
        "0",
        "--lr",
        "0.2",
        "--bandwidth-mbs",
        "1000",
        "--latency-ms",
        "250",
        "--retries",
        "2",
        "--retry-backoff-ms",
        "50",
        "--io-timeout-ms",
        "3000",
    ];
    worker_args.extend_from_slice(&common);
    let worker = spawn_bptcnn(&worker_args);

    // Signal only once the run is demonstrably mid-flight (two installs of
    // the eight the worker would complete).
    let mut server_log = String::new();
    let mut installs_seen = 0;
    let mut line = String::new();
    while installs_seen < 2 {
        line.clear();
        let n = server_err.read_line(&mut line).expect("read server stderr");
        assert!(n > 0, "server exited before two installs:\n{server_log}");
        if !install_versions(&line).is_empty() {
            installs_seen += 1;
        }
        server_log.push_str(&line);
    }
    bptcnn::util::signal::send_signal(server.id(), bptcnn::util::signal::SIGTERM).unwrap();

    let status = server.wait().expect("wait server");
    server_err.read_to_string(&mut server_log).unwrap();
    assert!(status.success(), "SIGTERM must exit 0, got {status:?}:\n{server_log}");
    let graceful = server_log
        .lines()
        .find(|l| l.contains("graceful shutdown at v"))
        .unwrap_or_else(|| panic!("no graceful-shutdown line:\n{server_log}"));

    // The final checkpoint captures exactly the drained version.
    let (version, _weights) =
        bptcnn::outer::read_checkpoint(&dir).expect("final checkpoint must be readable");
    let drained = digits_after(graceful, "at v").parse().unwrap();
    assert_eq!(version, drained, "checkpoint lags the drained state: {graceful}");
    assert!(version >= 2, "signal landed before the observed installs?");

    // The worker loses its server mid-run; reap it, exit status is its own
    // business (it may or may not have been inside its final iteration).
    let _ = worker.wait_with_output();
    std::fs::remove_dir_all(&dir).ok();
}
