//! Cross-module integration tests: full training flows over the real
//! in-process cluster, cross-backend parity, experiment smoke runs, and the
//! end-to-end composition the paper's architecture promises.

use std::sync::Arc;

use bptcnn::config::{
    ClusterConfig, NetworkConfig, PartitionStrategy, TrainConfig, UpdateStrategy,
};
use bptcnn::data::Dataset;
use bptcnn::nn::Network;
use bptcnn::outer::worker::LocalTrainer;
use bptcnn::outer::{train_native, NativeTrainer};
use bptcnn::sim::{simulate, SimConfig};

/// Timing-sensitive tests measure wall-clock sleeps; on a single-core runner
/// concurrent tests distort them, so they serialize on this lock. A panicking
/// timing test poisons the mutex; later tests recover the guard instead of
/// cascading unrelated failures.
static TIMING: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn timing_guard() -> std::sync::MutexGuard<'static, ()> {
    TIMING.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

fn quick_tc(update: UpdateStrategy, partition: PartitionStrategy) -> TrainConfig {
    TrainConfig {
        network: NetworkConfig::quickstart(),
        update,
        partition,
        total_samples: 512,
        iterations: 8,
        idpa_batches: 3,
        learning_rate: 0.3,
        seed: 99,
    }
}

/// The whole outer layer learns the synthetic task end-to-end with every
/// strategy combination.
#[test]
fn native_training_learns_under_all_strategies() {
    let cluster = ClusterConfig::heterogeneous(3, 5);
    for update in [UpdateStrategy::Agwu, UpdateStrategy::Sgwu] {
        for partition in [PartitionStrategy::Idpa, PartitionStrategy::Udpa] {
            let tc = quick_tc(update, partition);
            let r = train_native(&tc, &cluster);
            assert!(
                r.final_accuracy > 0.15,
                "{}+{} accuracy {} too low",
                update.name(),
                partition.name(),
                r.final_accuracy
            );
            // Note: the Eq.-16 square error of *softmax* outputs can rise
            // while accuracy improves (confident-but-occasionally-wrong
            // beats uniform in accuracy yet not in MSE), so accuracy above
            // chance is the learning criterion here; monotone-loss checks
            // live in the worker/e2e tests with longer horizons.
            assert!(
                r.final_accuracy > 1.5 * (1.0 / tc.network.num_classes as f64),
                "{}+{} final accuracy {} not above chance",
                update.name(),
                partition.name(),
                r.final_accuracy
            );
        }
    }
}

/// IDPA's allocations follow node speed; UDPA's don't. On a sharply skewed
/// cluster the IDPA run must end better balanced.
#[test]
fn idpa_beats_udpa_on_balance() {
    let _guard = timing_guard();
    let mut cluster = ClusterConfig::homogeneous(3);
    cluster.nodes[0].freq_ghz = 3.2;
    cluster.nodes[2].freq_ghz = 1.1;
    let idpa = train_native(&quick_tc(UpdateStrategy::Sgwu, PartitionStrategy::Idpa), &cluster);
    let udpa = train_native(&quick_tc(UpdateStrategy::Sgwu, PartitionStrategy::Udpa), &cluster);
    assert!(idpa.allocations[0] > idpa.allocations[2], "{:?}", idpa.allocations);
    assert!(udpa.allocations[0].abs_diff(udpa.allocations[2]) <= 1);
    assert!(
        idpa.balance_index > udpa.balance_index,
        "IDPA {} vs UDPA {}",
        idpa.balance_index,
        udpa.balance_index
    );
    assert!(idpa.sync_wait_s < udpa.sync_wait_s);
}

/// The accuracy-weighted SGWU merge (Eq. 7) of identical shards equals each
/// worker's own result: consensus sanity.
#[test]
fn sgwu_consensus_on_identical_shards() {
    let cfg = NetworkConfig::quickstart();
    let ds = Arc::new(Dataset::synthetic(&cfg, 32, 0.2, 77));
    // Two workers over the SAME indices → identical local training.
    let schedule = vec![vec![0..32, 0..32]];
    let workers: Vec<Box<dyn LocalTrainer>> = (0..2)
        .map(|_| Box::new(NativeTrainer::new(&cfg, Arc::clone(&ds), 0.2)) as Box<dyn LocalTrainer>)
        .collect();
    let init = Network::init(&cfg, 5).weights;
    let report = bptcnn::outer::run_sgwu(init.clone(), workers, &schedule, 2, None);

    let mut solo = NativeTrainer::new(&cfg, ds, 0.2);
    solo.add_samples(0..32);
    let mut w = init;
    for _ in 0..2 {
        w = solo.train_epoch(Arc::new(w)).weights;
    }
    assert!(
        report.final_weights.max_abs_diff(&w) < 1e-5,
        "consensus diff {}",
        report.final_weights.max_abs_diff(&w)
    );
}

/// Simulator and real cluster agree on the *direction* of every headline
/// claim at matched (small) scale.
#[test]
fn simulator_agrees_with_real_cluster_directionally() {
    let _guard = timing_guard();
    // Real cluster measurements.
    let mut cluster = ClusterConfig::homogeneous(3);
    cluster.nodes[2].freq_ghz = 1.0;
    cluster.nodes[0].freq_ghz = 3.0;
    let real_sync = train_native(&quick_tc(UpdateStrategy::Sgwu, PartitionStrategy::Udpa), &cluster);
    let real_async = train_native(&quick_tc(UpdateStrategy::Agwu, PartitionStrategy::Udpa), &cluster);
    assert!(real_sync.sync_wait_s > real_async.sync_wait_s);

    // Same scenario simulated.
    let base = SimConfig {
        network: NetworkConfig::quickstart(),
        cluster,
        update: UpdateStrategy::Sgwu,
        partition: PartitionStrategy::Udpa,
        samples: 512,
        iterations: 8,
        idpa_batches: 3,
        threads_per_node: 8,
        seed: 1,
    };
    let sim_sync = simulate(&base);
    let sim_async = simulate(&SimConfig { update: UpdateStrategy::Agwu, ..base.clone() });
    assert!(sim_sync.sync_wait_s > sim_async.sync_wait_s);
    assert!(sim_async.total_s <= sim_sync.total_s);
}

/// Experiment regenerators run end-to-end in quick mode (simulated figures).
#[test]
fn experiment_smoke_fig12_fig14_fig15() {
    for id in ["fig12", "fig13", "fig14", "fig15"] {
        let out = bptcnn::experiments::run(id, true).unwrap();
        assert!(out.contains("Fig."), "{id} produced no figure output");
        assert!(out.contains("BPT-CNN") || out.contains("AGWU"), "{id} missing rows");
    }
}

/// Full three-layer composition: artifacts → PJRT → distributed AGWU+IDPA
/// training (skips when artifacts are absent; compiled only with the real
/// PJRT backend — the default stub build would fail it even with artifacts).
#[cfg(feature = "xla-pjrt")]
#[test]
fn xla_distributed_training_end_to_end() {
    use bptcnn::runtime::{find_model_dir, XlaService, XlaTrainer};
    let Some(dir) = find_model_dir("quickstart") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let service = XlaService::start(&dir).unwrap();
    let network = service.handle().manifest.config.clone();
    let cluster = ClusterConfig::heterogeneous(2, 3);
    let tc = TrainConfig {
        network: network.clone(),
        update: UpdateStrategy::Agwu,
        partition: PartitionStrategy::Idpa,
        total_samples: 256,
        iterations: 4,
        idpa_batches: 2,
        learning_rate: 0.3,
        seed: 7,
    };
    let ds = Arc::new(Dataset::synthetic(&network, tc.total_samples, 0.3, tc.seed));
    let (schedule, _, iters) = bptcnn::outer::build_schedule(&tc, &cluster);
    let workers: Vec<Box<dyn LocalTrainer>> = (0..2)
        .map(|_| {
            Box::new(XlaTrainer::new(service.handle(), Arc::clone(&ds), 0.3))
                as Box<dyn LocalTrainer>
        })
        .collect();
    let init = service.handle().init_weights(7).unwrap();
    let report = bptcnn::outer::run_agwu(init, workers, &schedule, iters, None);
    assert_eq!(report.versions.len(), 2 * iters);
    let first = report.versions.first().unwrap().local_loss;
    let last = report.versions.last().unwrap().local_loss;
    assert!(last < first, "XLA distributed training did not learn: {first} → {last}");
}

/// ThreadPool::wait_idle under mixed `execute` / `execute_on` load from
/// several producer threads: every job runs exactly once, wait_idle returns
/// only after all of them, and repeated rounds don't wedge the pool.
#[test]
fn threadpool_wait_idle_stress_mixed_load() {
    use bptcnn::util::threadpool::ThreadPool;
    use std::sync::atomic::{AtomicUsize, Ordering};

    let pool = Arc::new(ThreadPool::new(4));
    for round in 0..5 {
        let shared_jobs = 150;
        let pinned_jobs = 150;
        let counter = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|scope| {
            for producer in 0..3 {
                let pool = Arc::clone(&pool);
                let counter = Arc::clone(&counter);
                scope.spawn(move || {
                    for i in 0..shared_jobs / 3 {
                        let c = Arc::clone(&counter);
                        pool.execute(move || {
                            if i % 17 == 0 {
                                std::thread::sleep(std::time::Duration::from_micros(50));
                            }
                            c.fetch_add(1, Ordering::SeqCst);
                        });
                        let c = Arc::clone(&counter);
                        pool.execute_on((producer + i) % pool.size(), move || {
                            c.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                });
            }
        });
        pool.wait_idle();
        assert_eq!(
            counter.load(Ordering::SeqCst),
            shared_jobs / 3 * 3 + pinned_jobs / 3 * 3,
            "round {round}: jobs lost or duplicated"
        );
    }
}

/// Eq. 11 holds on the real cluster: 2·m·K weight-set transfers.
#[test]
fn communication_matches_eq11_on_real_cluster() {
    let cluster = ClusterConfig::homogeneous(3);
    let tc = quick_tc(UpdateStrategy::Agwu, PartitionStrategy::Udpa);
    let r = train_native(&tc, &cluster);
    let expected_transfers = 2 * 3 * tc.iterations;
    assert_eq!(r.cluster.comm.fetches + r.cluster.comm.submits, expected_transfers);
    let expected_mb = (expected_transfers * tc.network.weight_bytes()) as f64 / (1024.0 * 1024.0);
    assert!((r.comm_mb - expected_mb).abs() < 1e-9);
}

/// PR6 tentpole: three real worker endpoints drive AGWU against the
/// standalone param-server service over loopback TCP. The run must produce
/// the same version/comm ledger shape as the in-process cluster (Eq. 11:
/// 2·m·K logical transfers), move real wire bytes, learn, and land within a
/// loose tolerance of an in-process AGWU run with identical trainers (AGWU
/// interleaving is nondeterministic in both deployments, so exact equality
/// is not expected here — see the SGWU test below for bitwise parity).
#[test]
fn tcp_loopback_agwu_three_workers_matches_inprocess() {
    use bptcnn::outer::{
        drive_worker, run_agwu, schedule_columns, serve, ServeOptions, Staleness, SubmitMode,
        TcpTransport,
    };
    use std::net::TcpListener;

    let cfg = NetworkConfig::quickstart();
    let ds = Arc::new(Dataset::synthetic(&cfg, 192, 0.3, 11));
    let init = Network::init(&cfg, 11).weights;
    let schedule = vec![vec![0..64, 64..128, 128..192]];
    let (m, iterations) = (3usize, 3usize);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = ServeOptions { nodes: m, update: UpdateStrategy::Agwu, ..ServeOptions::default() };
    let server = {
        let init = init.clone();
        std::thread::spawn(move || serve(listener, init, opts))
    };
    let handles: Vec<_> = schedule_columns(&schedule, m)
        .into_iter()
        .enumerate()
        .map(|(node, column)| {
            let (addr, ds, cfg) = (addr.clone(), Arc::clone(&ds), cfg.clone());
            std::thread::spawn(move || {
                let mut t = TcpTransport::connect(&addr, node).unwrap();
                let mut trainer = NativeTrainer::new(&cfg, ds, 0.2);
                drive_worker(
                    &mut t,
                    &mut trainer,
                    &column,
                    iterations,
                    SubmitMode::Agwu,
                    Staleness(0),
                    false,
                )
                .unwrap()
            })
        })
        .collect();
    let summaries: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let report = server.join().unwrap().unwrap();

    assert_eq!(report.versions.len(), m * iterations);
    assert_eq!(report.comm.fetches, m * iterations);
    assert_eq!(report.comm.submits, m * iterations);
    assert_eq!(report.comm.bytes, (2 * m * iterations * cfg.weight_bytes()) as u64);
    assert!(report.comm.wire_bytes > report.comm.bytes, "frames add protocol overhead");
    assert!(report.comm.comm_wall_s() > 0.0);
    for s in &summaries {
        assert_eq!(s.iterations, iterations);
        assert!(s.stats.wire_bytes > 0, "endpoint moved no bytes");
        assert!(s.busy_s > 0.0);
    }
    let first = report.versions.first().unwrap().local_loss;
    let last = report.versions.last().unwrap().local_loss;
    assert!(last < first, "TCP AGWU did not learn: {first} -> {last}");

    let workers: Vec<Box<dyn LocalTrainer>> = (0..m)
        .map(|_| Box::new(NativeTrainer::new(&cfg, Arc::clone(&ds), 0.2)) as Box<dyn LocalTrainer>)
        .collect();
    let inproc = run_agwu(init, workers, &schedule, iterations, None);
    assert_eq!(inproc.versions.len(), report.versions.len());
    let diff = report.final_weights.max_abs_diff(&inproc.final_weights);
    assert!(diff < 0.5, "TCP vs in-process AGWU diverged: max|Δw| = {diff}");
}

/// PR6 tentpole: SGWU is deterministic — submissions buffer at the barrier
/// and apply in node order regardless of arrival order — so a 2-worker SGWU
/// run over loopback TCP must be *bit-identical* to the in-process cluster
/// from the same init, dataset and schedule. This is the strongest parity
/// guarantee the transport refactor makes.
#[test]
fn tcp_loopback_sgwu_bitwise_matches_inprocess() {
    use bptcnn::outer::{
        drive_worker, run_sgwu, schedule_columns, serve, ServeOptions, Staleness, SubmitMode,
        TcpTransport,
    };
    use std::net::TcpListener;

    let cfg = NetworkConfig::quickstart();
    let ds = Arc::new(Dataset::synthetic(&cfg, 144, 0.3, 23));
    let init = Network::init(&cfg, 23).weights;
    // Two allocation batches → exercises incremental add_samples on both paths.
    let schedule = vec![vec![0..48, 48..96], vec![96..120, 120..144]];
    let (m, iterations) = (2usize, 2usize);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = ServeOptions { nodes: m, update: UpdateStrategy::Sgwu, ..ServeOptions::default() };
    let server = {
        let init = init.clone();
        std::thread::spawn(move || serve(listener, init, opts))
    };
    let handles: Vec<_> = schedule_columns(&schedule, m)
        .into_iter()
        .enumerate()
        .map(|(node, column)| {
            let (addr, ds, cfg) = (addr.clone(), Arc::clone(&ds), cfg.clone());
            std::thread::spawn(move || {
                let mut t = TcpTransport::connect(&addr, node).unwrap();
                let mut trainer = NativeTrainer::new(&cfg, ds, 0.25);
                drive_worker(
                    &mut t,
                    &mut trainer,
                    &column,
                    iterations,
                    SubmitMode::Sgwu,
                    Staleness(0),
                    false,
                )
                .unwrap()
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let report = server.join().unwrap().unwrap();

    let workers: Vec<Box<dyn LocalTrainer>> = (0..m)
        .map(|_| Box::new(NativeTrainer::new(&cfg, Arc::clone(&ds), 0.25)) as Box<dyn LocalTrainer>)
        .collect();
    let inproc = run_sgwu(init, workers, &schedule, iterations, None);

    // One installed version per round, flagged as the all-nodes merge.
    assert_eq!(report.versions.len(), iterations);
    assert!(report.versions.iter().all(|v| v.node == usize::MAX));
    assert_eq!(report.comm.fetches, inproc.comm.fetches);
    assert_eq!(report.comm.submits, inproc.comm.submits);
    assert_eq!(report.comm.bytes, inproc.comm.bytes);
    let diff = report.final_weights.max_abs_diff(&inproc.final_weights);
    assert_eq!(diff, 0.0, "SGWU over TCP must be bit-identical, got max|Δw| = {diff}");
}

/// PR8 tentpole: the pipelined worker loop (comm on a background thread,
/// snapshots allowed to lag ≤ 1 version) drives the same 3-worker AGWU
/// deployment over loopback TCP and must clear the same gates as the
/// serialized run: full Eq. 11 ledger, learning in the right direction, and
/// a final weight set within the serialized test's tolerance of an
/// in-process AGWU run — staleness changes interleaving, not convergence.
#[test]
fn tcp_loopback_pipelined_agwu_staleness1_matches_gates() {
    use bptcnn::outer::{
        drive_worker, run_agwu, schedule_columns, serve, ServeOptions, Staleness, SubmitMode,
        TcpTransport,
    };
    use std::net::TcpListener;

    let cfg = NetworkConfig::quickstart();
    let ds = Arc::new(Dataset::synthetic(&cfg, 192, 0.3, 11));
    let init = Network::init(&cfg, 11).weights;
    let schedule = vec![vec![0..64, 64..128, 128..192]];
    let (m, iterations) = (3usize, 3usize);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = ServeOptions { nodes: m, update: UpdateStrategy::Agwu, ..ServeOptions::default() };
    let server = {
        let init = init.clone();
        std::thread::spawn(move || serve(listener, init, opts))
    };
    let handles: Vec<_> = schedule_columns(&schedule, m)
        .into_iter()
        .enumerate()
        .map(|(node, column)| {
            let (addr, ds, cfg) = (addr.clone(), Arc::clone(&ds), cfg.clone());
            std::thread::spawn(move || {
                let mut t = TcpTransport::connect(&addr, node).unwrap();
                let mut trainer = NativeTrainer::new(&cfg, ds, 0.2);
                drive_worker(
                    &mut t,
                    &mut trainer,
                    &column,
                    iterations,
                    SubmitMode::Agwu,
                    Staleness(1),
                    false,
                )
                .unwrap()
            })
        })
        .collect();
    let summaries: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let report = server.join().unwrap().unwrap();

    // Same Eq. 11 ledger as the serialized deployment: the pipeline reorders
    // transfers, it does not add or drop any.
    assert_eq!(report.versions.len(), m * iterations);
    assert_eq!(report.comm.submits, m * iterations);
    assert!(report.comm.fetches >= m * iterations, "prefetches can only add fetches");
    for s in &summaries {
        assert_eq!(s.iterations, iterations);
        assert_eq!(s.ack_log.len(), iterations, "one ack per submitted epoch");
        assert!(s.max_staleness <= 1, "staleness bound violated: {}", s.max_staleness);
        assert!(s.stats.connect_wall_s > 0.0, "TCP connect wall not attributed");
        assert!(s.stats.wire_bytes > 0, "endpoint moved no bytes");
    }
    assert!(
        summaries.iter().any(|s| s.stats.max_inflight >= 1),
        "no worker ever had a request in flight — pipeline never overlapped"
    );
    let first = report.versions.first().unwrap().local_loss;
    let last = report.versions.last().unwrap().local_loss;
    assert!(last < first, "pipelined TCP AGWU did not learn: {first} -> {last}");

    let workers: Vec<Box<dyn LocalTrainer>> = (0..m)
        .map(|_| Box::new(NativeTrainer::new(&cfg, Arc::clone(&ds), 0.2)) as Box<dyn LocalTrainer>)
        .collect();
    let inproc = run_agwu(init, workers, &schedule, iterations, None);
    let diff = report.final_weights.max_abs_diff(&inproc.final_weights);
    assert!(diff < 0.5, "pipelined TCP vs in-process AGWU diverged: max|Δw| = {diff}");
}

/// PR9 tentpole: kill-and-recover. Three worker slots, AGWU, `--on-failure
/// continue`. The victim registers, fetches once, and dies without ever
/// submitting (its dropped socket is the crash). The server must declare it
/// dead, re-allocate both of its unconsumed IDPA batches to the survivors in
/// proportion to measured throughput (all-zero here → equal split), deliver
/// them piggybacked on the survivors' next fetch, and complete the run with
/// the loss still improving.
#[test]
fn tcp_agwu_kill_and_recover_survivors_absorb_dead_nodes_batches() {
    use bptcnn::config::OnFailure;
    use bptcnn::outer::{
        drive_worker, schedule_columns, serve, ServeOptions, Staleness, SubmitMode, TcpTransport,
        Transport,
    };
    use std::net::TcpListener;

    let cfg = NetworkConfig::quickstart();
    let ds = Arc::new(Dataset::synthetic(&cfg, 240, 0.3, 31));
    let init = Network::init(&cfg, 31).weights;
    // Two allocation batches per node (rows × nodes); node 2 owns 160..240.
    let schedule = vec![
        vec![0..40, 80..120, 160..200],
        vec![40..80, 120..160, 200..240],
    ];
    let (m, iterations) = (3usize, 4usize);
    let columns = schedule_columns(&schedule, m);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = ServeOptions {
        nodes: m,
        update: UpdateStrategy::Agwu,
        on_failure: OnFailure::Continue,
        schedule: Some(columns.clone()),
        ..ServeOptions::default()
    };
    let server = {
        let init = init.clone();
        std::thread::spawn(move || serve(listener, init, opts))
    };

    // The victim: node 2 registers and fetches, then its socket drops with
    // no Done — a kill -9 as the server sees it.
    {
        let mut victim = TcpTransport::connect(&addr, 2).unwrap();
        victim.fetch_global().unwrap();
    }
    // Let the server observe the EOF and re-allocate before the survivors
    // register, so their very first Global reply carries the extras.
    std::thread::sleep(std::time::Duration::from_millis(200));

    let handles: Vec<_> = columns
        .iter()
        .take(2)
        .cloned()
        .enumerate()
        .map(|(node, column)| {
            let (addr, ds, cfg) = (addr.clone(), Arc::clone(&ds), cfg.clone());
            std::thread::spawn(move || {
                let mut t = TcpTransport::connect(&addr, node).unwrap();
                let mut trainer = NativeTrainer::new(&cfg, ds, 0.2);
                let summary = drive_worker(
                    &mut t,
                    &mut trainer,
                    &column,
                    iterations,
                    SubmitMode::Agwu,
                    Staleness(0),
                    false,
                )
                .unwrap();
                (summary, trainer.sample_count())
            })
        })
        .collect();
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let report = server.join().unwrap().expect("run must survive the crash");

    // The dead node's two batches (80 samples) moved, none were lost: the
    // two survivors' shards now cover the full 240-sample dataset.
    assert_eq!(report.fault.reallocated_batches, 2);
    assert_eq!(report.fault.reallocated_samples, 80);
    assert_eq!(report.fault.leases_expired, 0, "death came from EOF, not a lease");
    let counts: Vec<usize> = results.iter().map(|(_, n)| *n).collect();
    assert_eq!(counts.iter().sum::<usize>(), 240, "samples lost or duplicated: {counts:?}");
    assert!(counts.iter().all(|&n| n > 80), "re-allocation not spread: {counts:?}");

    // Only the survivors contributed versions, and the run still learned.
    assert_eq!(report.versions.len(), 2 * iterations);
    let first = report.versions.first().unwrap().local_loss;
    let last = report.versions.last().unwrap().local_loss;
    assert!(last < first, "run did not keep learning after the crash: {first} -> {last}");
}

/// PR9 acceptance gate: `--resume` from a mid-run checkpoint reproduces the
/// uninterrupted run's final weights *bit-identically*. Single-node AGWU
/// with a one-batch shard is fully deterministic, so 2 epochs + (resume
/// from the v2 checkpoint) + 2 epochs must equal 4 straight epochs.
#[test]
fn checkpoint_resume_reproduces_bit_identical_weights() {
    use bptcnn::outer::{
        drive_worker, read_checkpoint, serve, ServeOptions, Staleness, SubmitMode, TcpTransport,
    };
    use bptcnn::tensor::WeightSet;
    use std::net::TcpListener;
    use std::path::PathBuf;

    let cfg = NetworkConfig::quickstart();
    let ds = Arc::new(Dataset::synthetic(&cfg, 96, 0.3, 41));
    let init = Network::init(&cfg, 41).weights;
    let column = vec![0..96]; // one batch: every epoch trains the same shard

    let run = |init: WeightSet,
               iters: usize,
               dir: Option<PathBuf>,
               init_version: usize,
               resumed: bool| {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let opts = ServeOptions {
            nodes: 1,
            update: UpdateStrategy::Agwu,
            checkpoint_dir: dir,
            checkpoint_every: 1,
            init_version,
            resumed,
            ..ServeOptions::default()
        };
        let server = std::thread::spawn(move || serve(listener, init, opts));
        let mut t = TcpTransport::connect(&addr, 0).unwrap();
        let mut trainer = NativeTrainer::new(&cfg, Arc::clone(&ds), 0.2);
        drive_worker(
            &mut t,
            &mut trainer,
            &column,
            iters,
            SubmitMode::Agwu,
            Staleness(0),
            false,
        )
        .unwrap();
        server.join().unwrap().unwrap()
    };

    let full = run(init.clone(), 4, None, 0, false);

    let dir = std::env::temp_dir().join(format!("bptcnn-ckpt-resume-{}", std::process::id()));
    let half = run(init, 2, Some(dir.clone()), 0, false);
    assert!(half.fault.checkpoints_written >= 2, "cadence 1 must checkpoint every version");

    let (version, restored) = read_checkpoint(&dir).unwrap();
    assert_eq!(version, 2);
    assert_eq!(
        restored.max_abs_diff(&half.final_weights),
        0.0,
        "latest checkpoint must capture the v2 state exactly"
    );

    let resumed = run(restored, 2, None, 2, true);
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(resumed.fault.checkpoints_loaded, 1);
    assert_eq!(
        resumed.versions.last().unwrap().version,
        full.versions.last().unwrap().version,
        "resumed run must continue the version sequence, not restart it"
    );
    let diff = resumed.final_weights.max_abs_diff(&full.final_weights);
    assert_eq!(diff, 0.0, "resume must be bit-identical to the unbroken run, got max|Δw| = {diff}");
}

/// PR9 satellite: a malformed frame is answered with a typed wire `Error`
/// the peer can actually read — the server holds its read side open until
/// the frame is collected (naively closing right after the write can turn
/// into a TCP RST that destroys it) — and the run aborts as a protocol
/// violation.
#[test]
fn tcp_malformed_frame_gets_typed_error_reply_and_aborts_run() {
    use bptcnn::outer::wire::{read_msg, Msg};
    use bptcnn::outer::{serve, ServeOptions};
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let init = Network::init(&NetworkConfig::quickstart(), 3).weights;
    let server =
        std::thread::spawn(move || serve(listener, init, ServeOptions::default()));

    let mut s = TcpStream::connect(addr).unwrap();
    // A well-formed frame header carrying an unknown tag where Hello is
    // expected: the decoder must reject it without reading further.
    s.write_all(&1u32.to_le_bytes()).unwrap();
    s.write_all(&[0xEE]).unwrap();
    s.flush().unwrap();

    let (msg, _) = read_msg(&mut s).unwrap();
    match msg {
        Msg::Error { msg } => {
            assert!(msg.contains("bad hello"), "unexpected error text: {msg}")
        }
        other => panic!("expected a typed Error frame, got {other:?}"),
    }
    drop(s);

    let err = server.join().unwrap().expect_err("protocol violation must fail the run");
    assert!(format!("{err:#}").contains("bad hello"), "{err:#}");
}

/// PR9 satellite: the evicted-base straggler fallback (history window cap
/// `2m+2`) under the *pipelined* worker loop. A gated straggler holds its
/// v0 snapshot while the other node installs 12 versions; its eventual
/// submit's base has left the window, the server falls back to the oldest
/// retained version, counts it, and the run still completes.
#[test]
fn pipelined_straggler_takes_evicted_base_fallback() {
    use bptcnn::outer::{
        drive_worker, EpochOutcome, InProcTransport, ParamServer, Staleness, SubmitMode,
        SubmitMeta, Transport,
    };
    use bptcnn::tensor::WeightSet;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Mutex;

    /// Signals `started` when its first epoch begins, then blocks until
    /// `go` — freezing the straggler at a v0 base for as long as the test
    /// needs the fast node to run ahead.
    struct GatedTrainer {
        started: Arc<AtomicBool>,
        go: Arc<AtomicBool>,
        samples: usize,
    }
    impl LocalTrainer for GatedTrainer {
        fn train_epoch(&mut self, start: Arc<WeightSet>) -> EpochOutcome {
            self.started.store(true, Ordering::Release);
            while !self.go.load(Ordering::Acquire) {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            let mut w = (*start).clone();
            w.tensors_mut()[0].data_mut()[0] += 0.01;
            EpochOutcome {
                weights: w,
                loss: 1.0,
                accuracy: 0.5,
                samples: self.samples.max(1),
                compute_s: 0.0,
            }
        }
        fn add_samples(&mut self, range: std::ops::Range<usize>) {
            self.samples += range.len();
        }
        fn sample_count(&self) -> usize {
            self.samples
        }
    }

    let cfg = NetworkConfig::quickstart();
    let init = Network::init(&cfg, 51).weights;
    let ps = Arc::new(Mutex::new(ParamServer::new(init, 2)));
    let started = Arc::new(AtomicBool::new(false));
    let go = Arc::new(AtomicBool::new(false));

    let straggler = {
        let ps = Arc::clone(&ps);
        let (started, go) = (Arc::clone(&started), Arc::clone(&go));
        std::thread::spawn(move || {
            let mut t = InProcTransport::new(ps, 0);
            let mut trainer = GatedTrainer { started, go, samples: 8 };
            drive_worker(
                &mut t,
                &mut trainer,
                &[0..8],
                2,
                SubmitMode::Agwu,
                Staleness(1),
                false,
            )
            .unwrap()
        })
    };

    // Wait until the straggler holds its v0 snapshot, then install 12
    // versions from the fast node — more than the 2m+2 = 6 the history
    // window retains, guaranteeing v0 is gone.
    while !started.load(Ordering::Acquire) {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    let mut fast = InProcTransport::new(Arc::clone(&ps), 1);
    for _ in 0..12 {
        let (w, base) = fast.fetch_global().unwrap();
        let local = (*w).clone();
        let meta = SubmitMeta {
            mode: SubmitMode::Agwu,
            base,
            accuracy: 0.5,
            loss: 1.0,
            want_snapshot: false,
        };
        fast.submit(local, &meta).unwrap();
    }
    go.store(true, Ordering::Release);

    let summary = straggler.join().unwrap();
    assert_eq!(summary.iterations, 2);
    assert!(summary.max_staleness <= 1, "pipeline bound violated: {}", summary.max_staleness);
    let fallbacks = ps.lock().unwrap().comm.evicted_base_fallbacks;
    assert!(
        fallbacks >= 1,
        "straggler's v0 base should have been evicted and counted, got {fallbacks}"
    );
}
