//! Allocation regression for the workspace train step: after one warmup
//! step, `Network::train_batch_ws` must perform **zero** heap allocations —
//! the weight packs repack in place, every intermediate lives in the
//! [`StepWorkspace`] arenas, and the gradient set is reused.
//!
//! The counting allocator wraps `System` and counts every `alloc` /
//! `alloc_zeroed` / `realloc`. This file deliberately contains a single
//! test: integration-test binaries get their own process, so no concurrent
//! test can pollute the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use bptcnn::config::NetworkConfig;
use bptcnn::data::Dataset;
use bptcnn::inner::{AutoTuner, ScheduleStats, StageKey, StageKind};
use bptcnn::nn::{Network, StepWorkspace};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: a transparent wrapper over `System` — every method bumps the
// counter (no allocator re-entry: `fetch_add` on a static atomic never
// allocates) and forwards `ptr`/`layout` unchanged, so `System` upholds the
// `GlobalAlloc` contract on our behalf.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: forwards the caller's layout to `System.alloc` verbatim.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: forwards the caller's layout to `System.alloc_zeroed` verbatim.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    // SAFETY: `ptr`/`layout` come from this allocator, which always handed
    // out `System` pointers, so forwarding them to `System.realloc` is valid.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: same provenance argument as `realloc` — `ptr` originated from
    // `System`, so `System.dealloc` may free it.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn warmed_up_train_batch_is_allocation_free() {
    // Conv + FC stack deep enough to exercise every stage (two conv layers
    // so the packed input-gradient path runs, two FC layers plus the output
    // layer so the ping-pong delta buffers and all pack slots are used).
    let cfg = NetworkConfig {
        name: "alloc".into(),
        input_hw: 8,
        in_channels: 1,
        conv_layers: 2,
        filters: 4,
        kernel_hw: 3,
        fc_layers: 2,
        fc_neurons: 16,
        num_classes: 4,
        batch_size: 8,
        pool_window: 2,
    };
    assert_zero_alloc_steps(&cfg, 8);
    // The ISSUE-4 regime: small batch × FC wide enough to span several
    // NR-column panels (ragged — 100 = 12×8 + 4), so the serial step rides
    // the panel-windowed kernels the 2D tiles share. Those entry points
    // must stay allocation-free too.
    let wide = NetworkConfig {
        name: "alloc_wide_fc".into(),
        input_hw: 8,
        in_channels: 1,
        conv_layers: 1,
        filters: 4,
        kernel_hw: 3,
        fc_layers: 2,
        fc_neurons: 100,
        num_classes: 4,
        batch_size: 4,
        pool_window: 2,
    };
    assert_zero_alloc_steps(&wide, 4);
    // ISSUE-5: the TilePolicy::Auto bookkeeping must live in pre-sized
    // node-owned state — a locked tuner's steady-state plan/observe cycle
    // makes zero heap allocations, so routing a warmed-up step through the
    // autotuner adds no allocation on top of the step itself. (Same
    // process/test so the global counter stays unpolluted.)
    assert_locked_tuner_is_allocation_free();
}

fn assert_locked_tuner_is_allocation_free() {
    let mut tuner = AutoTuner::new(7);
    // The ISSUE-4/-5 acceptance shapes: small-batch wide FC (forward +
    // backward) plus a conv stage.
    let keys = [
        StageKey::new(StageKind::DenseFwd, 4, 2000, 2000, 8),
        StageKey::new(StageKind::DenseBwd, 4, 2000, 2000, 8),
        StageKey::new(StageKind::ConvFwd, 64, 72, 8, 8),
    ];
    // Reusable stats carcass: the measurement window below only mutates its
    // scalar makespan (constructing one allocates its per-thread vectors).
    let mut stats = ScheduleStats {
        makespan_s: 1e-3,
        thread_busy_s: vec![1e-4; 8],
        thread_assigned_cost: vec![1.0; 8],
        tasks: 16,
    };
    // Drive every stage through its exploration window with a
    // deterministic synthetic makespan until all lock.
    for _ in 0..400 {
        for k in &keys {
            let g = tuner.plan(*k, 1);
            stats.makespan_s = 1e-4 * (1.0 + g.tiles() as f64);
            tuner.observe(*k, &stats);
        }
        if keys.iter().all(|k| tuner.stage(k).map_or(false, |s| s.locked())) {
            break;
        }
    }
    assert!(
        keys.iter().all(|k| tuner.stage(k).unwrap().locked()),
        "tuner failed to lock within the window"
    );

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..100 {
        for k in &keys {
            let g = tuner.plan(*k, 1);
            stats.makespan_s = 1e-4 * (1.0 + g.tiles() as f64);
            tuner.observe(*k, &stats);
        }
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "locked autotuner made {} heap allocations over 300 plan/observe cycles",
        after - before
    );
}

fn assert_zero_alloc_steps(cfg: &NetworkConfig, batch: usize) {
    let ds = Dataset::synthetic(cfg, 32, 0.2, 7);
    let (x, y, _) = ds.batch(0, batch);
    let mut net = Network::init(cfg, 1);
    let mut ws = StepWorkspace::new();

    // Warmup: sizes the workspace arenas and the weight-pack slots.
    let mut warm_loss = 0.0;
    for _ in 0..3 {
        let (l, _) = net.train_batch_ws(&x, &y, batch, 0.1, &mut ws);
        warm_loss = l;
    }

    let before = ALLOCS.load(Ordering::SeqCst);
    let mut last_loss = warm_loss;
    for _ in 0..10 {
        let (l, _) = net.train_batch_ws(&x, &y, batch, 0.1, &mut ws);
        last_loss = l;
    }
    let after = ALLOCS.load(Ordering::SeqCst);

    assert_eq!(
        after - before,
        0,
        "[{}] warmed-up train_batch_ws made {} heap allocations over 10 steps",
        cfg.name,
        after - before
    );
    // Sanity: the measured steps actually trained.
    assert!(last_loss.is_finite());
    assert!(
        last_loss < warm_loss * 1.5,
        "[{}] loss diverged: {warm_loss} -> {last_loss}",
        cfg.name
    );
}
