//! Allocation regression for the workspace train step: after one warmup
//! step, `Network::train_batch_ws` must perform **zero** heap allocations —
//! the weight packs repack in place, every intermediate lives in the
//! [`StepWorkspace`] arenas, and the gradient set is reused.
//!
//! The counting allocator wraps `System` and counts every `alloc` /
//! `alloc_zeroed` / `realloc`. This file deliberately contains a single
//! test: integration-test binaries get their own process, so no concurrent
//! test can pollute the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use bptcnn::config::NetworkConfig;
use bptcnn::data::Dataset;
use bptcnn::nn::{Network, StepWorkspace};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn warmed_up_train_batch_is_allocation_free() {
    // Conv + FC stack deep enough to exercise every stage (two conv layers
    // so the packed input-gradient path runs, two FC layers plus the output
    // layer so the ping-pong delta buffers and all pack slots are used).
    let cfg = NetworkConfig {
        name: "alloc".into(),
        input_hw: 8,
        in_channels: 1,
        conv_layers: 2,
        filters: 4,
        kernel_hw: 3,
        fc_layers: 2,
        fc_neurons: 16,
        num_classes: 4,
        batch_size: 8,
        pool_window: 2,
    };
    assert_zero_alloc_steps(&cfg, 8);
    // The ISSUE-4 regime: small batch × FC wide enough to span several
    // NR-column panels (ragged — 100 = 12×8 + 4), so the serial step rides
    // the panel-windowed kernels the 2D tiles share. Those entry points
    // must stay allocation-free too.
    let wide = NetworkConfig {
        name: "alloc_wide_fc".into(),
        input_hw: 8,
        in_channels: 1,
        conv_layers: 1,
        filters: 4,
        kernel_hw: 3,
        fc_layers: 2,
        fc_neurons: 100,
        num_classes: 4,
        batch_size: 4,
        pool_window: 2,
    };
    assert_zero_alloc_steps(&wide, 4);
}

fn assert_zero_alloc_steps(cfg: &NetworkConfig, batch: usize) {
    let ds = Dataset::synthetic(cfg, 32, 0.2, 7);
    let (x, y, _) = ds.batch(0, batch);
    let mut net = Network::init(cfg, 1);
    let mut ws = StepWorkspace::new();

    // Warmup: sizes the workspace arenas and the weight-pack slots.
    let mut warm_loss = 0.0;
    for _ in 0..3 {
        let (l, _) = net.train_batch_ws(&x, &y, batch, 0.1, &mut ws);
        warm_loss = l;
    }

    let before = ALLOCS.load(Ordering::SeqCst);
    let mut last_loss = warm_loss;
    for _ in 0..10 {
        let (l, _) = net.train_batch_ws(&x, &y, batch, 0.1, &mut ws);
        last_loss = l;
    }
    let after = ALLOCS.load(Ordering::SeqCst);

    assert_eq!(
        after - before,
        0,
        "[{}] warmed-up train_batch_ws made {} heap allocations over 10 steps",
        cfg.name,
        after - before
    );
    // Sanity: the measured steps actually trained.
    assert!(last_loss.is_finite());
    assert!(
        last_loss < warm_loss * 1.5,
        "[{}] loss diverged: {warm_loss} -> {last_loss}",
        cfg.name
    );
}
