//! Property-based tests over the system's invariants, via the hand-rolled
//! `util::prop` harness (seeded, sized, reproducible with PROP_SEED).

use bptcnn::config::NetworkConfig;
use bptcnn::inner::{execute_dag, mark_priorities, TaskDag};
use bptcnn::nn::ops::{self, ConvDims};
use bptcnn::outer::{udpa_partition, IdpaPartitioner, ParamServer};
use bptcnn::tensor::{Tensor, WeightSet};
use bptcnn::util::json::Json;
use bptcnn::util::prop::{self, assert_close, assert_eq_msg, assert_true};
use bptcnn::util::stats;
use bptcnn::util::threadpool::ThreadPool;

/// IDPA conservation: batches 1..A−1 allocate exactly ⌊N/A⌋ samples each,
/// the final batch absorbs the N mod A remainder, so Σ totals == N exactly —
/// for random cluster shapes, speeds and batch counts. (The seed dropped up
/// to A−1 samples; this property is the regression guard.)
#[test]
fn prop_idpa_conserves_quota() {
    prop::check("idpa conservation", 150, |g| {
        let m = g.usize_full(1, 12);
        let a = g.usize_full(1, 8);
        let n = g.usize(a * m, 50_000).max(a * m);
        let freqs: Vec<f64> = (0..m).map(|_| g.f64(0.5, 4.0)).collect();
        let speeds: Vec<f64> = (0..m).map(|_| g.f64(1e-4, 1e-2)).collect();
        let mut p = IdpaPartitioner::new(n, a, &freqs);
        let totals = p.run_with_oracle(|j| speeds[j]);
        let quota = n / a;
        for (i, batch) in p.allocations().iter().enumerate() {
            let expect = if i + 1 == a { quota + n % a } else { quota };
            assert_eq_msg(batch.iter().sum::<usize>(), expect, &format!("batch {i}"))?;
        }
        assert_eq_msg(totals.iter().sum::<usize>(), n, "Σ totals == N")
    });
}

/// UDPA: uniform within ±1, conserves N exactly.
#[test]
fn prop_udpa_uniform() {
    prop::check("udpa uniform", 200, |g| {
        let n = g.usize(0, 1_000_000);
        let m = g.usize_full(1, 40);
        let sizes = udpa_partition(n, m);
        assert_eq_msg(sizes.iter().sum::<usize>(), n, "conservation")?;
        let mx = *sizes.iter().max().unwrap();
        let mn = *sizes.iter().min().unwrap();
        assert_true(mx - mn <= 1, "uniformity within 1")
    });
}

/// SGWU with equal accuracies is the arithmetic mean; with one dominant
/// accuracy it converges to that node's weights (Eq. 7 limits).
#[test]
fn prop_sgwu_weighted_mean_limits() {
    prop::check("sgwu limits", 100, |g| {
        let m = g.usize_full(2, 6);
        let len = g.usize_full(1, 64);
        let sets: Vec<WeightSet> = (0..m)
            .map(|_| WeightSet::new(vec![Tensor::from_vec(&[len], g.vec_f32(len, -2.0, 2.0))]))
            .collect();
        // Equal accuracies → mean.
        let mut ps = ParamServer::new(sets[0].zeros_like(), m);
        let locals: Vec<(WeightSet, f64)> = sets.iter().map(|s| (s.clone(), 0.7)).collect();
        ps.update_sgwu(&locals);
        for i in 0..len {
            let mean: f64 = sets.iter().map(|s| s.tensors()[0].data()[i] as f64).sum::<f64>() / m as f64;
            assert_close(ps.global().tensors()[0].data()[i] as f64, mean, 1e-5, "mean")?;
        }
        // Dominant accuracy → near that set.
        let mut ps2 = ParamServer::new(sets[0].zeros_like(), m);
        let mut locals2: Vec<(WeightSet, f64)> = sets.iter().map(|s| (s.clone(), 1e-9)).collect();
        locals2[0].1 = 1.0;
        ps2.update_sgwu(&locals2);
        assert_true(
            ps2.global().max_abs_diff(&sets[0]) < 1e-3,
            "dominant accuracy wins",
        )
    });
}

/// AGWU γ weights (Eq. 9): positive, and monotone in the base version —
/// fresher bases never get *less* weight.
#[test]
fn prop_gamma_monotone_in_freshness() {
    prop::check("gamma monotone", 100, |g| {
        let m = g.usize_full(2, 8);
        let len = 4;
        let init = WeightSet::new(vec![Tensor::zeros(&[len])]);
        let mut ps = ParamServer::new(init, m);
        // Random update history.
        let rounds = g.usize_full(1, 20);
        for _ in 0..rounds {
            let node = g.usize_full(0, m - 1);
            let (w, k) = ps.fetch(node);
            ps.update_agwu(node, &w, k, g.f64(0.1, 1.0));
        }
        let v = ps.version();
        let k1 = g.usize_full(0, v);
        let k2 = g.usize_full(k1, v);
        let g1 = ps.gamma(0, k1);
        let g2 = ps.gamma(0, k2);
        assert_true(g1 > 0.0 && g2 > 0.0, "positive")?;
        assert_true(g2 >= g1 - 1e-12, "monotone in freshness")
    });
}

/// The Algorithm-4.2 scheduler never violates dependency order on random
/// layered DAGs, and every task runs exactly once.
#[test]
fn prop_scheduler_topological_safety() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    prop::check("scheduler safety", 30, |g| {
        let layers = g.usize_full(1, 4);
        let width = g.usize_full(1, 10);
        let threads = g.usize_full(1, 4);
        let mut dag: TaskDag<usize> = TaskDag::new();
        let mut prev: Vec<usize> = Vec::new();
        let mut id = 0usize;
        for l in 0..layers {
            let mut cur = Vec::new();
            for _ in 0..width {
                let deps: Vec<usize> = if l == 0 || prev.is_empty() {
                    vec![]
                } else {
                    let k = g.usize_full(0, prev.len().min(3));
                    (0..k).map(|_| *g.choose(&prev)).collect()
                };
                cur.push(dag.add("t", g.f64(0.5, 2.0), &deps, id));
                id += 1;
            }
            prev = cur;
        }
        let n = dag.len();
        let deps: Vec<Vec<usize>> = dag.nodes().iter().map(|nd| nd.deps.clone()).collect();
        let pool = ThreadPool::new(threads);
        let seq = Arc::new(AtomicUsize::new(0));
        let pos: Arc<Vec<AtomicUsize>> =
            Arc::new((0..n).map(|_| AtomicUsize::new(usize::MAX)).collect());
        {
            let seq = Arc::clone(&seq);
            let pos = Arc::clone(&pos);
            execute_dag(&pool, dag, move |_, &tid| {
                pos[tid].store(seq.fetch_add(1, Ordering::SeqCst), Ordering::SeqCst);
            });
        }
        for (tid, dl) in deps.iter().enumerate() {
            let my = pos[tid].load(Ordering::SeqCst);
            assert_true(my != usize::MAX, "task ran")?;
            for &d in dl {
                assert_true(
                    pos[d].load(Ordering::SeqCst) < my,
                    &format!("dep {d} before task {tid}"),
                )?;
            }
        }
        Ok(())
    });
}

/// Priority marking: priorities strictly decrease along any edge.
#[test]
fn prop_priorities_decrease_along_edges() {
    prop::check("priority edges", 100, |g| {
        let n = g.usize_full(1, 40);
        let mut dag: TaskDag<()> = TaskDag::new();
        for i in 0..n {
            let deps: Vec<usize> = if i == 0 {
                vec![]
            } else {
                let k = g.usize_full(0, 3.min(i));
                (0..k).map(|_| g.usize_full(0, i - 1)).collect()
            };
            dag.add("t", 1.0, &deps, ());
        }
        let pri = mark_priorities(&dag);
        for node in dag.nodes() {
            for &d in &node.deps {
                assert_true(pri[d] > pri[node.id], "upstream higher priority")?;
            }
        }
        Ok(())
    });
}

/// The im2col + packed-GEMM conv forward matches the retained naive
/// reference across randomized `ConvDims`: kernels {1, …, 5} (odd *and*
/// even), C_in/C_out up to 8 (crossing the NR=8 panel width), batch up to 4,
/// rectangular spatial dims down to 1×1 (including W < k, heavy padding).
#[test]
fn prop_im2col_gemm_fwd_matches_naive() {
    prop::check("im2col gemm fwd parity", 60, |g| {
        let k = *g.choose(&[1usize, 2, 3, 4, 5]);
        let d = ConvDims {
            n: g.usize_full(1, 4),
            h: g.usize_full(1, 12),
            w: g.usize_full(1, 12),
            c: g.usize_full(1, 8),
            k,
            co: g.usize_full(1, 8),
        };
        let x = g.vec_f32(d.x_len(), -1.0, 1.0);
        let f = g.vec_f32(d.f_len(), -1.0, 1.0);
        let bias = g.vec_f32(d.co, -0.5, 0.5);
        let mut fast = vec![0.0f32; d.y_len()];
        let mut naive = vec![0.0f32; d.y_len()];
        ops::conv2d_same_fwd(&d, &x, &f, &bias, &mut fast);
        ops::conv2d_same_fwd_naive(&d, &x, &f, &bias, &mut naive);
        for (i, (a, b)) in fast.iter().zip(naive.iter()).enumerate() {
            assert_close(*a as f64, *b as f64, 1e-4, &format!("y[{i}] ({d:?})"))?;
        }
        Ok(())
    });
}

/// Both conv backward passes (input gradient via the flipped-filter GEMM
/// path, filter/bias gradient via patchesᵀ·dy) match the naive reference.
#[test]
fn prop_im2col_gemm_bwd_matches_naive() {
    prop::check("im2col gemm bwd parity", 40, |g| {
        let k = *g.choose(&[1usize, 2, 3, 4, 5]);
        let d = ConvDims {
            n: g.usize_full(1, 4),
            h: g.usize_full(1, 10),
            w: g.usize_full(1, 10),
            c: g.usize_full(1, 8),
            k,
            co: g.usize_full(1, 8),
        };
        let x = g.vec_f32(d.x_len(), -1.0, 1.0);
        let f = g.vec_f32(d.f_len(), -1.0, 1.0);
        let dy = g.vec_f32(d.y_len(), -1.0, 1.0);
        let mut dx_fast = vec![0.0f32; d.x_len()];
        let mut dx_naive = vec![0.0f32; d.x_len()];
        ops::conv2d_same_bwd_input(&d, &dy, &f, &mut dx_fast);
        ops::conv2d_same_bwd_input_naive(&d, &dy, &f, &mut dx_naive);
        for (i, (a, b)) in dx_fast.iter().zip(dx_naive.iter()).enumerate() {
            assert_close(*a as f64, *b as f64, 1e-4, &format!("dx[{i}] ({d:?})"))?;
        }
        let mut df_fast = vec![0.0f32; d.f_len()];
        let mut db_fast = vec![0.0f32; d.co];
        let mut df_naive = vec![0.0f32; d.f_len()];
        let mut db_naive = vec![0.0f32; d.co];
        ops::conv2d_same_bwd_filter(&d, &x, &dy, &mut df_fast, &mut db_fast);
        ops::conv2d_same_bwd_filter_naive(&d, &x, &dy, &mut df_naive, &mut db_naive);
        for (i, (a, b)) in df_fast.iter().zip(df_naive.iter()).enumerate() {
            assert_close(*a as f64, *b as f64, 1e-4, &format!("df[{i}] ({d:?})"))?;
        }
        for (i, (a, b)) in db_fast.iter().zip(db_naive.iter()).enumerate() {
            assert_close(*a as f64, *b as f64, 1e-4, &format!("db[{i}] ({d:?})"))?;
        }
        Ok(())
    });
}

/// The task-parallel conv (Algorithm 4.1 tiles on the pool) matches the
/// naive reference for random shapes, granularities and pool sizes.
#[test]
fn prop_conv_parallel_matches_naive() {
    use bptcnn::inner::conv2d_parallel;
    prop::check("parallel conv parity", 25, |g| {
        let k = *g.choose(&[1usize, 2, 3, 4, 5]);
        let d = ConvDims {
            n: g.usize_full(1, 4),
            h: g.usize_full(1, 10),
            w: g.usize_full(1, 10),
            c: g.usize_full(1, 6),
            k,
            co: g.usize_full(1, 6),
        };
        let x = g.vec_f32(d.x_len(), -1.0, 1.0);
        let f = g.vec_f32(d.f_len(), -1.0, 1.0);
        let bias = g.vec_f32(d.co, -0.5, 0.5);
        let mut naive = vec![0.0f32; d.y_len()];
        ops::conv2d_same_fwd_naive(&d, &x, &f, &bias, &mut naive);
        let pool = ThreadPool::new(g.usize_full(1, 4));
        let rows = g.usize_full(1, d.h);
        let mut par = vec![0.0f32; d.y_len()];
        conv2d_parallel(&pool, &d, &x, &f, &bias, &mut par, rows);
        for (i, (a, b)) in par.iter().zip(naive.iter()).enumerate() {
            assert_close(*a as f64, *b as f64, 1e-4, &format!("y[{i}] rows={rows}"))?;
        }
        Ok(())
    });
}

/// The row-tile backward (per-worker arena accumulation, no mutex) matches
/// the serial oracles for random shapes (odd *and* even kernels, W < k),
/// granularities and pool sizes — and a second, differently-shaped layer
/// call on the *same pool* still matches, proving scratch/partial contents
/// of a previous layer call cannot leak through the arenas.
#[test]
fn prop_conv_bwd_parallel_matches_naive_and_arenas_do_not_leak() {
    use bptcnn::inner::bp_tasks::conv_bwd_parallel;
    prop::check("row-tile bwd parity + arena reuse", 15, |g| {
        let pool = ThreadPool::new(g.usize_full(1, 4));
        for round in 0..2 {
            let k = *g.choose(&[1usize, 2, 3, 4, 5]);
            let d = ConvDims {
                n: g.usize_full(1, 4),
                h: g.usize_full(1, 9),
                w: g.usize_full(1, 9),
                c: g.usize_full(1, 5),
                k,
                co: g.usize_full(1, 5),
            };
            let x = g.vec_f32(d.x_len(), -1.0, 1.0);
            let f = g.vec_f32(d.f_len(), -1.0, 1.0);
            let dy = g.vec_f32(d.y_len(), -1.0, 1.0);
            let mut df_s = vec![0.0f32; d.f_len()];
            let mut db_s = vec![0.0f32; d.co];
            let mut dx_s = vec![0.0f32; d.x_len()];
            ops::conv2d_same_bwd_filter_naive(&d, &x, &dy, &mut df_s, &mut db_s);
            ops::conv2d_same_bwd_input_naive(&d, &dy, &f, &mut dx_s);
            let rows = g.usize_full(1, d.h);
            let mut df_p = vec![0.0f32; d.f_len()];
            let mut db_p = vec![0.0f32; d.co];
            let mut dx_p = vec![0.0f32; d.x_len()];
            conv_bwd_parallel(&pool, &d, &x, &f, &dy, &mut df_p, &mut db_p, Some(&mut dx_p), rows);
            for (i, (a, b)) in df_p.iter().zip(df_s.iter()).enumerate() {
                let msg = format!("df[{i}] round={round} ({d:?})");
                assert_close(*a as f64, *b as f64, 1e-3, &msg)?;
            }
            for (i, (a, b)) in db_p.iter().zip(db_s.iter()).enumerate() {
                assert_close(*a as f64, *b as f64, 1e-3, &format!("db[{i}] round={round}"))?;
            }
            for (i, (a, b)) in dx_p.iter().zip(dx_s.iter()).enumerate() {
                assert_close(*a as f64, *b as f64, 1e-3, &format!("dx[{i}] round={round}"))?;
            }
        }
        Ok(())
    });
}

/// Packed dense forward/backward parity vs the naive triple loops across
/// ragged `(m, k, n)` shapes — `n` not a multiple of NR=8, `k < MR=4`,
/// single-row batches `m = 1` — including the transposed pack used for
/// `dx = dy · Wᵀ`. The FC stack rides the same micro-kernel as conv, so
/// this is the dense analogue of the im2col-GEMM parity properties.
#[test]
fn prop_dense_packed_matches_naive() {
    prop::check("packed dense parity", 80, |g| {
        let m = g.usize_full(1, 9);
        let k = g.usize_full(1, 19);
        let n = g.usize_full(1, 19);
        let x = g.vec_f32(m * k, -1.0, 1.0);
        let w = g.vec_f32(k * n, -1.0, 1.0);
        let b = g.vec_f32(n, -0.5, 0.5);
        let mut fwd_naive = vec![0.0f32; m * n];
        ops::dense_fwd(m, k, n, &x, &w, &b, &mut fwd_naive);
        let packed = ops::PackedB::pack(k, n, &w);
        let mut fwd_fast = vec![0.0f32; m * n];
        ops::dense_fwd_packed(m, &x, &packed, &b, &mut fwd_fast);
        for (i, (a, bb)) in fwd_fast.iter().zip(fwd_naive.iter()).enumerate() {
            assert_close(*a as f64, *bb as f64, 1e-4, &format!("out[{i}] m={m} k={k} n={n}"))?;
        }
        let dy = g.vec_f32(m * n, -1.0, 1.0);
        let mut dx_n = vec![0.0f32; m * k];
        let mut dw_n = vec![0.0f32; k * n];
        let mut db_n = vec![0.0f32; n];
        ops::dense_bwd(m, k, n, &x, &w, &dy, &mut dx_n, &mut dw_n, &mut db_n);
        let wt = ops::PackedB::pack_transposed(k, n, &w);
        let mut dx_p = vec![0.0f32; m * k];
        let mut dw_p = vec![0.0f32; k * n];
        let mut db_p = vec![0.0f32; n];
        ops::dense_bwd_packed(m, k, n, &x, &wt, &dy, &mut dx_p, &mut dw_p, &mut db_p);
        for (i, (a, bb)) in dx_p.iter().zip(dx_n.iter()).enumerate() {
            assert_close(*a as f64, *bb as f64, 1e-4, &format!("dx[{i}] m={m} k={k} n={n}"))?;
        }
        for (i, (a, bb)) in dw_p.iter().zip(dw_n.iter()).enumerate() {
            assert_close(*a as f64, *bb as f64, 1e-4, &format!("dw[{i}] m={m} k={k} n={n}"))?;
        }
        for (i, (a, bb)) in db_p.iter().zip(db_n.iter()).enumerate() {
            assert_close(*a as f64, *bb as f64, 1e-4, &format!("db[{i}] m={m} k={k} n={n}"))?;
        }
        Ok(())
    });
}

/// The FC 2D-tile backward (per-worker arena stripe accumulation + reduce,
/// ReLU mask fused, dx panel tiles behind the mask barrier) matches the
/// serial packed reference for random shapes — `n`/`k` not multiples of
/// NR=8, `m` smaller than the pool — across random row *and* panel
/// granularities (panel tiles forced via explicit grids, so both the fused
/// row-only path and the two-phase 2D path are exercised).
#[test]
fn prop_fc_2d_tile_bwd_matches_serial() {
    use bptcnn::inner::{dense_bwd_parallel, panel_count, TileGrid};
    prop::check("fc 2d-tile bwd parity", 25, |g| {
        let m = g.usize_full(1, 8);
        let k = g.usize_full(1, 24);
        let n = g.usize_full(1, 24);
        let pool = ThreadPool::new(g.usize_full(1, 4));
        let x = g.vec_f32(m * k, -1.0, 1.0);
        let w = g.vec_f32(k * n, -1.0, 1.0);
        let dy0 = g.vec_f32(m * n, -1.0, 1.0);
        let mut relu_out = g.vec_f32(m * n, -1.0, 1.0);
        ops::relu_fwd(&mut relu_out);
        let wt = ops::PackedB::pack_transposed(k, n, &w);
        // Serial reference: explicit mask, then packed backward.
        let mut dy_s = dy0.clone();
        ops::relu_bwd(&relu_out, &mut dy_s);
        let mut dx_s = vec![0.0f32; m * k];
        let mut dw_s = vec![0.0f32; k * n];
        let mut db_s = vec![0.0f32; n];
        ops::dense_bwd_packed(m, k, n, &x, &wt, &dy_s, &mut dx_s, &mut dw_s, &mut db_s);
        let rows = g.usize_full(1, m);
        let panels_n = panel_count(n);
        let panels_k = panel_count(k);
        let ppt_n = g.usize_full(1, panels_n);
        let ppt_k = g.usize_full(1, panels_k);
        let dy_grid = TileGrid {
            rows_per_tile: rows,
            row_tiles: (m + rows - 1) / rows,
            panels_per_tile: ppt_n,
            panel_tiles: (panels_n + ppt_n - 1) / ppt_n,
        };
        let dx_grid = TileGrid {
            rows_per_tile: rows,
            row_tiles: (m + rows - 1) / rows,
            panels_per_tile: ppt_k,
            panel_tiles: (panels_k + ppt_k - 1) / ppt_k,
        };
        let mut dy_p = dy0.clone();
        let mut dx_p = vec![0.0f32; m * k];
        let mut dw_p = vec![0.0f32; k * n];
        let mut db_p = vec![0.0f32; n];
        dense_bwd_parallel(
            &pool,
            m,
            k,
            n,
            &x,
            &wt,
            &mut dy_p,
            Some(&relu_out),
            &mut dx_p,
            &mut dw_p,
            &mut db_p,
            dy_grid,
            dx_grid,
        );
        let tag = format!("rows={rows} ppt_n={ppt_n} ppt_k={ppt_k}");
        for (i, (a, b)) in dy_p.iter().zip(dy_s.iter()).enumerate() {
            assert_close(*a as f64, *b as f64, 1e-6, &format!("mask[{i}] {tag}"))?;
        }
        for (i, (a, b)) in dx_p.iter().zip(dx_s.iter()).enumerate() {
            assert_close(*a as f64, *b as f64, 1e-3, &format!("dx[{i}] {tag}"))?;
        }
        for (i, (a, b)) in dw_p.iter().zip(dw_s.iter()).enumerate() {
            assert_close(*a as f64, *b as f64, 1e-3, &format!("dw[{i}] {tag}"))?;
        }
        for (i, (a, b)) in db_p.iter().zip(db_s.iter()).enumerate() {
            assert_close(*a as f64, *b as f64, 1e-3, &format!("db[{i}] {tag}"))?;
        }
        Ok(())
    });
}

/// 2D-tiled dense forward (random row × panel grids, fused ReLU) is
/// bit-identical to the serial packed path — and the tile planner always
/// yields ≥ workers tiles for FC-shaped stages once the per-stage work
/// crosses its floor, with the acceptance shape (batch 4, 2000-neuron, 8
/// workers) pinned exactly.
#[test]
fn prop_dense_2d_fwd_parity_and_planner_supply() {
    use bptcnn::inner::{dense_fwd_parallel, panel_count, plan_tile_grid, TileGrid};
    prop::check("dense 2d fwd parity + planner", 30, |g| {
        let m = g.usize_full(1, 8);
        let k = g.usize_full(1, 24);
        let n = g.usize_full(1, 24);
        let workers = g.usize_full(1, 4);
        let pool = ThreadPool::new(workers);
        let x = g.vec_f32(m * k, -1.0, 1.0);
        let w = g.vec_f32(k * n, -1.0, 1.0);
        let b = g.vec_f32(n, -0.5, 0.5);
        let packed = ops::PackedB::pack(k, n, &w);
        let mut serial = vec![0.0f32; m * n];
        ops::dense_fwd_packed(m, &x, &packed, &b, &mut serial);
        ops::relu_fwd(&mut serial);
        let rows = g.usize_full(1, m);
        let panels = panel_count(n);
        let ppt = g.usize_full(1, panels);
        let grid = TileGrid {
            rows_per_tile: rows,
            row_tiles: (m + rows - 1) / rows,
            panels_per_tile: ppt,
            panel_tiles: (panels + ppt - 1) / ppt,
        };
        let mut par = vec![0.0f32; m * n];
        dense_fwd_parallel(&pool, m, &x, &packed, &b, &mut par, true, grid);
        for (i, (a, bb)) in par.iter().zip(serial.iter()).enumerate() {
            assert_eq_msg(*a, *bb, &format!("out[{i}] rows={rows} ppt={ppt}"))?;
        }
        // Planner supply: wide-FC stages above the work floor always
        // produce at least `workers` tiles, however small the batch.
        let wide = plan_tile_grid(m, 2000, 2000, workers, 1);
        assert_true(
            wide.tiles() >= workers,
            &format!("planner starves workers: {wide:?} (m={m} workers={workers})"),
        )?;
        let accept = plan_tile_grid(4, 2000, 2000, 8, 1);
        assert_true(accept.tiles() >= 8, &format!("acceptance shape under-tiled: {accept:?}"))
    });
}

/// 2D conv tiles (forced channel-panel splits) match the serial packed conv
/// across random shapes — co crossing several NR panels, small batches,
/// 1×1 spatial extents where rows alone cannot parallelize — for forward,
/// and the planner-driven backward (`conv_bwd_parallel`) stays correct on
/// wide-channel shapes that trigger real column splits.
#[test]
fn prop_conv_2d_tiles_match_serial() {
    use bptcnn::inner::bp_tasks::conv_bwd_parallel;
    use bptcnn::inner::{conv2d_parallel_packed, panel_count, TileGrid};
    prop::check("conv 2d tile parity", 12, |g| {
        let k = *g.choose(&[1usize, 3, 5]);
        let d = ConvDims {
            n: g.usize_full(1, 3),
            h: g.usize_full(1, 5),
            w: g.usize_full(1, 5),
            c: g.usize_full(1, 12),
            k,
            co: g.usize_full(9, 20), // ≥ 2 output panels
        };
        let x = g.vec_f32(d.x_len(), -1.0, 1.0);
        let f = g.vec_f32(d.f_len(), -1.0, 1.0);
        let bias = g.vec_f32(d.co, -0.5, 0.5);
        let mut serial = vec![0.0f32; d.y_len()];
        ops::conv2d_same_fwd(&d, &x, &f, &bias, &mut serial);
        let pool = ThreadPool::new(g.usize_full(2, 4));
        let packed = ops::pack_filter(&d, &f);
        let panels = panel_count(d.co);
        let ppt = g.usize_full(1, panels);
        let rows = g.usize_full(1, d.h);
        let grid = TileGrid {
            rows_per_tile: rows,
            row_tiles: (d.n * d.h + rows - 1) / rows,
            panels_per_tile: ppt,
            panel_tiles: (panels + ppt - 1) / ppt,
        };
        let mut par = vec![0.0f32; d.y_len()];
        conv2d_parallel_packed(&pool, &d, &x, &packed, &bias, &mut par, grid);
        for (i, (a, b)) in par.iter().zip(serial.iter()).enumerate() {
            assert_close(*a as f64, *b as f64, 1e-4, &format!("y[{i}] rows={rows} ppt={ppt}"))?;
        }
        // Planner-driven backward on the same wide-channel shape.
        let dy = g.vec_f32(d.y_len(), -1.0, 1.0);
        let mut df_s = vec![0.0f32; d.f_len()];
        let mut db_s = vec![0.0f32; d.co];
        let mut dx_s = vec![0.0f32; d.x_len()];
        ops::conv2d_same_bwd_filter_naive(&d, &x, &dy, &mut df_s, &mut db_s);
        ops::conv2d_same_bwd_input_naive(&d, &dy, &f, &mut dx_s);
        let mut df_p = vec![0.0f32; d.f_len()];
        let mut db_p = vec![0.0f32; d.co];
        let mut dx_p = vec![0.0f32; d.x_len()];
        conv_bwd_parallel(&pool, &d, &x, &f, &dy, &mut df_p, &mut db_p, Some(&mut dx_p), rows);
        for (i, (a, b)) in df_p.iter().zip(df_s.iter()).enumerate() {
            assert_close(*a as f64, *b as f64, 1e-3, &format!("df[{i}] ({d:?})"))?;
        }
        for (i, (a, b)) in db_p.iter().zip(db_s.iter()).enumerate() {
            assert_close(*a as f64, *b as f64, 1e-3, &format!("db[{i}] ({d:?})"))?;
        }
        for (i, (a, b)) in dx_p.iter().zip(dx_s.iter()).enumerate() {
            assert_close(*a as f64, *b as f64, 1e-3, &format!("dx[{i}] ({d:?})"))?;
        }
        Ok(())
    });
}

/// Conv forward/backward algebra: ⟨conv(x), dy⟩ == ⟨x, conv_bwd_input(dy)⟩
/// (adjoint identity) for random shapes.
#[test]
fn prop_conv_adjoint_identity() {
    prop::check("conv adjoint", 40, |g| {
        let d = ConvDims {
            n: g.usize_full(1, 3),
            h: g.usize_full(3, 8),
            w: g.usize_full(3, 8),
            c: g.usize_full(1, 3),
            k: 3,
            co: g.usize_full(1, 3),
        };
        let x = g.vec_f32(d.x_len(), -1.0, 1.0);
        let f = g.vec_f32(d.f_len(), -1.0, 1.0);
        let dy = g.vec_f32(d.y_len(), -1.0, 1.0);
        let zero_bias = vec![0.0f32; d.co];
        let mut y = vec![0.0f32; d.y_len()];
        ops::conv2d_same_fwd(&d, &x, &f, &zero_bias, &mut y);
        let mut dx = vec![0.0f32; d.x_len()];
        ops::conv2d_same_bwd_input(&d, &dy, &f, &mut dx);
        let lhs: f64 = y.iter().zip(&dy).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        let rhs: f64 = x.iter().zip(&dx).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        assert_close(lhs, rhs, 1e-3, "⟨Ax,y⟩=⟨x,Aᵀy⟩")
    });
}

/// JSON round-trip on random values.
#[test]
fn prop_json_roundtrip() {
    fn random_json(g: &mut prop::Gen, depth: usize) -> Json {
        match if depth == 0 { g.usize_full(0, 3) } else { g.usize_full(0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.f64(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => Json::Str(
                (0..g.usize_full(0, 12))
                    .map(|_| *g.choose(&['a', 'π', '"', '\\', '\n', 'z', ' ']))
                    .collect(),
            ),
            4 => Json::Arr((0..g.usize_full(0, 4)).map(|_| random_json(g, depth - 1)).collect()),
            _ => Json::Obj(
                (0..g.usize_full(0, 4))
                    .map(|i| (format!("k{i}"), random_json(g, depth - 1)))
                    .collect(),
            ),
        }
    }
    prop::check("json roundtrip", 200, |g| {
        let v = random_json(g, 3);
        let text = v.to_string();
        let back = Json::parse(&text).map_err(|e| format!("reparse failed: {e} on {text}"))?;
        assert_eq_msg(back, v, "roundtrip")
    });
}

/// Balance index: bounded in (0, 1], equals 1 for uniform loads, and is
/// scale-invariant.
#[test]
fn prop_balance_index_properties() {
    prop::check("balance index", 200, |g| {
        let n = g.usize_full(1, 30);
        let loads = g.vec_f64(n, 0.1, 100.0);
        let b = stats::balance_index(&loads);
        assert_true(b > 0.0 && b <= 1.0 + 1e-12, "bounded")?;
        let scaled: Vec<f64> = loads.iter().map(|x| x * 7.5).collect();
        assert_close(stats::balance_index(&scaled), b, 1e-9, "scale invariant")?;
        let uniform = vec![g.f64(0.1, 10.0); n];
        assert_close(stats::balance_index(&uniform), 1.0, 1e-9, "uniform = 1")
    });
}

/// Weight-set algebra: axpy/sub/scale satisfy vector-space identities.
#[test]
fn prop_weightset_vector_space() {
    prop::check("weightset algebra", 150, |g| {
        let len = g.usize_full(1, 100);
        let a = WeightSet::new(vec![Tensor::from_vec(&[len], g.vec_f32(len, -5.0, 5.0))]);
        let b = WeightSet::new(vec![Tensor::from_vec(&[len], g.vec_f32(len, -5.0, 5.0))]);
        // (a − b) + b == a
        let mut r = a.sub(&b);
        r.axpy(1.0, &b);
        assert_true(r.max_abs_diff(&a) < 1e-4, "(a−b)+b = a")?;
        // a + 0·b == a
        let mut r2 = a.clone();
        r2.axpy(0.0, &b);
        assert_eq_msg(r2.max_abs_diff(&a), 0.0, "a+0b = a")?;
        // ‖a‖ ≥ 0 and byte size consistent.
        assert_true(a.l2_norm() >= 0.0, "norm")?;
        assert_eq_msg(a.byte_size(), len * 4, "bytes")
    });
}

/// Network config ↔ manifest consistency across the whole Table-2 space:
/// param counting is exact for arbitrary well-formed configs.
#[test]
fn prop_param_count_matches_shapes() {
    prop::check("param manifest", 100, |g| {
        let cfg = NetworkConfig {
            name: "prop".into(),
            input_hw: *g.choose(&[8usize, 12, 16]),
            in_channels: g.usize_full(1, 3),
            conv_layers: g.usize_full(0, 4),
            filters: g.usize_full(1, 8),
            kernel_hw: *g.choose(&[1usize, 3, 5]),
            fc_layers: g.usize_full(0, 3),
            fc_neurons: g.usize_full(1, 64),
            num_classes: g.usize_full(2, 10),
            batch_size: 4,
            pool_window: 2,
        };
        let total: usize = cfg
            .param_shapes()
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum();
        assert_eq_msg(cfg.param_count(), total, "count = Σ shapes")?;
        assert_eq_msg(cfg.weight_bytes(), total * 4, "bytes = 4·count")
    });
}

/// ISSUE-5: any grid the autotuner can emit — the cold-start prior, every
/// explored neighbor (±1 row/column split, floor×{½,2} replans), and the
/// locked plan — produces **bit-identical** dense forward output to the
/// serial packed path, on ragged shapes (`n`, `k` ∤ NR, batch smaller than
/// the pool). The tuner is fed the real measured stats, so the walk is the
/// production exploration path.
#[test]
fn prop_autotuner_grids_bitwise_match_serial() {
    use bptcnn::inner::{dense_fwd_parallel, AutoTuner, StageKey, StageKind};
    prop::check("autotuner grid parity", 10, |g| {
        let m = g.usize_full(1, 6);
        let k = g.usize_full(1, 32);
        let n = g.usize_full(9, 40); // ≥ 2 panels so column neighbors exist
        let workers = g.usize_full(1, 4);
        let pool = ThreadPool::new(workers);
        let x = g.vec_f32(m * k, -1.0, 1.0);
        let w = g.vec_f32(k * n, -1.0, 1.0);
        let b = g.vec_f32(n, -0.5, 0.5);
        let packed = ops::PackedB::pack(k, n, &w);
        let mut serial = vec![0.0f32; m * n];
        ops::dense_fwd_packed(m, &x, &packed, &b, &mut serial);
        let mut tuner = AutoTuner::new(g.u64(0, u64::MAX / 2));
        let key = StageKey::new(StageKind::DenseFwd, m, k, n, workers);
        let mut locked_checked = false;
        for step in 0..48 {
            let grid = tuner.plan(key, 1);
            let mut par = vec![0.0f32; m * n];
            let stats = dense_fwd_parallel(&pool, m, &x, &packed, &b, &mut par, false, grid);
            for (i, (a, s)) in par.iter().zip(serial.iter()).enumerate() {
                assert_eq_msg(*a, *s, &format!("out[{i}] step={step} grid={grid:?}"))?;
            }
            tuner.observe(key, &stats);
            if tuner.stage(&key).map_or(false, |s| s.locked()) {
                locked_checked = true;
                break;
            }
        }
        assert_true(locked_checked, "tuner never locked within 48 steps")
    });
}

/// ISSUE-5: tuning decisions are reproducible under a fixed exploration
/// seed — two tuners with the same seed, fed the identical synthetic
/// makespan stream, plan the identical grid sequence and lock the
/// identical plan, for random stage shapes.
#[test]
fn prop_autotuner_decisions_deterministic_under_seed() {
    use bptcnn::inner::{AutoTuner, StageKey, StageKind, TileGrid};
    prop::check("autotuner determinism", 40, |g| {
        let m = g.usize_full(1, 8);
        let k = g.usize_full(1, 64);
        let n = g.usize_full(1, 64);
        let workers = g.usize_full(1, 8);
        let seed = g.u64(0, u64::MAX / 2);
        let key = StageKey::new(StageKind::DenseBwd, m, k, n, workers);
        let cost = |t: TileGrid| {
            (t.tiles() as f64 - (2 * workers) as f64).abs() + 0.1 * t.rows_per_tile as f64
        };
        let mut a = AutoTuner::new(seed);
        let mut b = AutoTuner::new(seed);
        let mut plans_a: Vec<TileGrid> = Vec::new();
        let mut plans_b: Vec<TileGrid> = Vec::new();
        for _ in 0..64 {
            let ga = a.plan(key, 1);
            let gb = b.plan(key, 1);
            plans_a.push(ga);
            plans_b.push(gb);
            a.observe_raw(key, cost(ga), 1.0);
            b.observe_raw(key, cost(gb), 1.0);
        }
        assert_true(
            plans_a == plans_b,
            &format!("decision streams diverged:\n{plans_a:?}\nvs\n{plans_b:?}"),
        )?;
        assert_eq_msg(
            a.stage(&key).unwrap().locked(),
            b.stage(&key).unwrap().locked(),
            "lock state diverged",
        )
    });
}

/// PR6: the WeightSet wire codec round-trips every f32 bit pattern exactly
/// (NaN payloads, infinities, signed zeros, denormals) for arbitrary tensor
/// shapes up to MAX_NDIM — including zero-sized dims — and rejects every
/// strict prefix, any corrupted header byte, and trailing garbage. A damaged
/// frame can never decode into a silently-wrong weight set.
#[test]
fn prop_weightset_codec_bit_exact_and_rejects_corruption() {
    use bptcnn::tensor::wire::{decode_weight_set, encode_weight_set, encoded_len, MAX_NDIM};
    prop::check("weightset codec", 120, |g| {
        let n_tensors = g.usize_full(0, 4);
        let mut tensors = Vec::with_capacity(n_tensors);
        for _ in 0..n_tensors {
            let ndim = g.usize_full(1, MAX_NDIM);
            // First two dims carry the size (possibly zero); trailing dims
            // stay tiny so the payload is bounded regardless of rank.
            let shape: Vec<usize> = (0..ndim)
                .map(|i| if i < 2 { g.usize_full(0, 5) } else { g.usize_full(1, 2) })
                .collect();
            let len: usize = shape.iter().product();
            let mut data = g.vec_f32(len, -1e6, 1e6);
            for v in data.iter_mut() {
                if g.usize_full(0, 3) == 0 {
                    *v = f32::from_bits(*g.choose(&[
                        f32::NAN.to_bits() | 0x1234, // NaN with payload bits
                        f32::INFINITY.to_bits(),
                        f32::NEG_INFINITY.to_bits(),
                        0x8000_0000, // -0.0
                        0x0000_0001, // smallest denormal
                        0xFFFF_FFFF, // negative quiet NaN, full payload
                    ]));
                }
            }
            tensors.push(Tensor::from_vec(&shape, data));
        }
        let ws = WeightSet::new(tensors);
        let enc = encode_weight_set(&ws);
        assert_eq_msg(enc.len(), encoded_len(&ws), "encoded_len exact")?;
        let dec = match decode_weight_set(&enc) {
            Ok(d) => d,
            Err(e) => return Err(format!("decode failed: {e}")),
        };
        assert_eq_msg(dec.len(), ws.len(), "tensor count")?;
        for (i, (a, b)) in dec.tensors().iter().zip(ws.tensors()).enumerate() {
            assert_eq_msg(a.shape(), b.shape(), &format!("shape of tensor {i}"))?;
            let ab: Vec<u32> = a.data().iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u32> = b.data().iter().map(|v| v.to_bits()).collect();
            assert_true(ab == bb, &format!("payload bits of tensor {i}"))?;
        }
        // Any strict prefix is rejected: the decoder demands the buffer be
        // consumed exactly, so a cut frame always errors.
        let cut = g.usize_full(0, enc.len() - 1);
        assert_true(
            decode_weight_set(&enc[..cut]).is_err(),
            &format!("truncation at {cut}/{} accepted", enc.len()),
        )?;
        // Flipping any header byte (magic, version, tensor count) is fatal.
        let mut bad = enc.clone();
        let idx = g.usize_full(0, 9);
        bad[idx] ^= 0xFF;
        assert_true(
            decode_weight_set(&bad).is_err(),
            &format!("corrupt header byte {idx} accepted"),
        )?;
        // So is trailing garbage after a well-formed payload.
        let mut long = enc;
        long.push(0);
        assert_true(decode_weight_set(&long).is_err(), "trailing byte accepted")
    });
}

/// PR8: the pipelined worker never trains on a snapshot more than `s`
/// versions behind the newest version it has seen acked — for arbitrary
/// staleness bounds, iteration counts, jittered comm timing, and a phantom
/// peer racing its own AGWU updates into the server around this worker's
/// transport calls. Also pinned: exactly one ack per epoch in strictly
/// increasing version order, the Eq. 11 submit count is exact, and every
/// fetch beyond one-per-epoch is an accounted staleness refetch.
#[test]
fn prop_pipelined_staleness_bound_holds_under_chaos() {
    use bptcnn::outer::{
        drive_worker, EpochOutcome, InProcTransport, LocalTrainer, Staleness, SubmitAck,
        SubmitMeta, SubmitMode, Transport, TransportStats,
    };
    use std::sync::{Arc, Mutex};

    /// In-process transport with deterministic chaos: jittered operation
    /// timing, and a phantom peer (node 1) that lands its own AGWU updates
    /// around this worker's operations — so the server version advances
    /// underneath the prefetched snapshots, exactly the interleaving the
    /// staleness bound exists to police.
    struct ChaosTransport {
        inner: InProcTransport,
        ps: Arc<Mutex<ParamServer>>,
        rng: u64,
        /// Percent chance a phantom update brackets each operation.
        phantom_pct: u64,
        jitter_us_max: u64,
    }

    impl ChaosTransport {
        fn next(&mut self) -> u64 {
            let mut x = self.rng;
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            self.rng = x;
            x
        }

        fn chaos(&mut self) {
            if self.jitter_us_max > 0 {
                let us = self.next() % (self.jitter_us_max + 1);
                std::thread::sleep(std::time::Duration::from_micros(us));
            }
            if self.next() % 100 < self.phantom_pct {
                let mut ps = self.ps.lock().unwrap();
                let (w, base) = ps.fetch(1);
                ps.update_agwu(1, &w, base, 0.5);
            }
        }
    }

    impl Transport for ChaosTransport {
        fn fetch_global(&mut self) -> anyhow::Result<(Arc<WeightSet>, usize)> {
            self.chaos();
            let out = self.inner.fetch_global();
            self.chaos();
            out
        }

        fn submit(&mut self, local: WeightSet, meta: &SubmitMeta) -> anyhow::Result<SubmitAck> {
            self.chaos();
            let out = self.inner.submit(local, meta);
            self.chaos();
            out
        }

        fn stats(&self) -> TransportStats {
            self.inner.stats()
        }
    }

    /// Minimal trainer: bounded fake compute, deterministic weight nudge.
    struct NudgeTrainer {
        samples: usize,
        spin_us: u64,
    }

    impl LocalTrainer for NudgeTrainer {
        fn train_epoch(&mut self, start: Arc<WeightSet>) -> EpochOutcome {
            let t0 = std::time::Instant::now();
            if self.spin_us > 0 {
                std::thread::sleep(std::time::Duration::from_micros(self.spin_us));
            }
            let mut w = (*start).clone();
            w.tensors_mut()[0].data_mut()[0] += 0.01;
            EpochOutcome {
                weights: w,
                loss: 1.0,
                accuracy: 0.5,
                samples: self.samples.max(1),
                compute_s: t0.elapsed().as_secs_f64(),
            }
        }
        fn add_samples(&mut self, range: std::ops::Range<usize>) {
            self.samples += range.len();
        }
        fn sample_count(&self) -> usize {
            self.samples
        }
    }

    prop::check("pipelined staleness bound", 40, |g| {
        let s = g.usize_full(1, 3);
        let iterations = g.usize_full(2, 6);
        let init = WeightSet::new(vec![Tensor::zeros(&[8])]);
        let ps = Arc::new(Mutex::new(ParamServer::new(init, 2)));
        let mut t = ChaosTransport {
            inner: InProcTransport::new(Arc::clone(&ps), 0),
            ps: Arc::clone(&ps),
            rng: g.u64(1, u64::MAX / 2) | 1,
            phantom_pct: g.usize_full(0, 90) as u64,
            jitter_us_max: g.usize_full(0, 200) as u64,
        };
        let mut trainer = NudgeTrainer { samples: 4, spin_us: g.usize_full(0, 200) as u64 };
        let summary = drive_worker(
            &mut t,
            &mut trainer,
            &[],
            iterations,
            SubmitMode::Agwu,
            Staleness(s),
            false,
        )
        .map_err(|e| format!("pipelined worker failed: {e}"))?;

        assert_true(
            summary.max_staleness <= s,
            &format!("bound violated: trained {} behind with s={s}", summary.max_staleness),
        )?;
        assert_eq_msg(summary.ack_log.len(), iterations, "one ack per epoch")?;
        for pair in summary.ack_log.windows(2) {
            assert_true(
                pair[0].version < pair[1].version,
                &format!("acks out of order: v{} then v{}", pair[0].version, pair[1].version),
            )?;
        }
        assert_eq_msg(summary.stats.submits, iterations, "Eq. 11 submit count exact")?;
        assert_true(summary.stats.fetches >= iterations, "refetches can only add fetches")?;
        assert_eq_msg(
            summary.staleness_refetches,
            summary.stats.fetches - iterations,
            "every extra fetch is an accounted refetch",
        )?;
        drop(t);
        let ps = Arc::try_unwrap(ps).unwrap().into_inner().unwrap();
        assert_true(ps.version() >= iterations, "server version includes all submits")
    });
}

/// PR9: chaos — the pipelined worker (`s ≥ 1`) keeps every PR8 invariant
/// when its transport injects seeded drops, truncations, duplicated frames
/// and delays, and a [`RetryingTransport`] reconnects through them. Faults
/// fire *before* the underlying operation, so a retried submit is never
/// double-applied: the ack stream must stay strictly increasing with
/// exactly one ack per epoch, the staleness bound must hold, and the
/// recovery ledger must stay internally consistent.
#[test]
fn prop_pipelined_chaos_retries_preserve_invariants() {
    use bptcnn::outer::{
        drive_worker, ConnectFn, EpochOutcome, FaultyTransport, InProcTransport, LocalTrainer,
        RetryPolicy, RetryingTransport, Staleness, SubmitMode, Transport,
    };
    use std::sync::{Arc, Mutex};
    use std::time::Duration;

    /// Minimal trainer: bounded fake compute, deterministic weight nudge.
    struct NudgeTrainer {
        samples: usize,
        spin_us: u64,
    }

    impl LocalTrainer for NudgeTrainer {
        fn train_epoch(&mut self, start: Arc<WeightSet>) -> EpochOutcome {
            let t0 = std::time::Instant::now();
            if self.spin_us > 0 {
                std::thread::sleep(std::time::Duration::from_micros(self.spin_us));
            }
            let mut w = (*start).clone();
            w.tensors_mut()[0].data_mut()[0] += 0.01;
            EpochOutcome {
                weights: w,
                loss: 1.0,
                accuracy: 0.5,
                samples: self.samples.max(1),
                compute_s: t0.elapsed().as_secs_f64(),
            }
        }
        fn add_samples(&mut self, range: std::ops::Range<usize>) {
            self.samples += range.len();
        }
        fn sample_count(&self) -> usize {
            self.samples
        }
    }

    prop::check("pipelined chaos with retries", 40, |g| {
        let s = g.usize_full(1, 2);
        let iterations = g.usize_full(2, 6);
        let drop_pct = g.usize_full(0, 30) as u8;
        let truncate_pct = g.usize_full(0, 15) as u8;
        let duplicate_pct = g.usize_full(0, 30) as u8;
        let delay_pct = g.usize_full(0, 30) as u8;
        let bitflip_pct = g.usize_full(0, 15) as u8;
        let base_seed = g.u64(1, u64::MAX / 2) | 1;

        let init = WeightSet::new(vec![Tensor::zeros(&[8])]);
        let ps = Arc::new(Mutex::new(ParamServer::new(init, 1)));
        // Every (re)connection gets a fresh fault stream derived from the
        // session counter, so reconnecting never replays the same faults.
        let connect: ConnectFn = {
            let ps = Arc::clone(&ps);
            let mut session = 0u64;
            Box::new(move || {
                session += 1;
                let inner = InProcTransport::new(Arc::clone(&ps), 0);
                let faulty = FaultyTransport::new(inner, base_seed.wrapping_mul(session) | 1)
                    .with_drop_pct(drop_pct)
                    .with_truncate_pct(truncate_pct)
                    .with_duplicate_pct(duplicate_pct)
                    .with_bitflip_pct(bitflip_pct)
                    .with_delay(delay_pct, Duration::from_micros(50));
                Ok(Box::new(faulty) as Box<dyn Transport>)
            })
        };
        // 32 attempts at ≤ 60% per-op fatal-fault rate (drop + truncate +
        // CRC-rejected bit flip): the chance of exhausting the budget is
        // ~1e-7 per operation — deterministic enough for CI.
        let policy = RetryPolicy {
            max_attempts: 32,
            base_backoff: Duration::from_micros(10),
            max_backoff: Duration::from_micros(500),
        };
        let mut t = RetryingTransport::new(connect, policy);
        let mut trainer = NudgeTrainer { samples: 4, spin_us: g.usize_full(0, 200) as u64 };
        let summary = drive_worker(
            &mut t,
            &mut trainer,
            &[],
            iterations,
            SubmitMode::Agwu,
            Staleness(s),
            false,
        )
        .map_err(|e| format!("chaos worker failed: {e:#}"))?;

        assert_true(
            summary.max_staleness <= s,
            &format!("bound violated: trained {} behind with s={s}", summary.max_staleness),
        )?;
        assert_eq_msg(summary.ack_log.len(), iterations, "one ack per epoch")?;
        for pair in summary.ack_log.windows(2) {
            assert_true(
                pair[0].version < pair[1].version,
                &format!("acks out of order: v{} then v{}", pair[0].version, pair[1].version),
            )?;
        }
        // Faults fire before the wrapped call, so each epoch lands exactly
        // one server-side update regardless of how many retries it took.
        let ledger = summary.stats.fault;
        assert_true(
            ledger.reconnects <= ledger.retries,
            &format!("{} reconnects but only {} retries", ledger.reconnects, ledger.retries),
        )?;
        drop(t);
        let ps = Arc::try_unwrap(ps).unwrap().into_inner().unwrap();
        assert_eq_msg(ps.version(), iterations, "exactly one installed version per epoch")?;
        assert_eq_msg(ps.comm.submits, iterations, "no duplicated submits")
    });
}
