"""Layer-2 JAX model: the paper's CNN forward/backward + SGD training step.

The network follows §3.1 of the paper (Fig. 1): a feature extractor of
``conv_layers`` convolutional layers (Eq. 1, each followed by ReLU and SAME
padding so Table-2 depths stay well-formed), one mean-pooling layer, and a
fully-connected classifier of ``fc_layers`` hidden layers with ``fc_neurons``
each (Fig. 1's classifier). The loss is the square error of the output layer
(Eq. 16), and weights are updated by SGD (Eq. 23).

Every convolution, pooling and FC layer calls the Layer-1 Pallas kernels in
``compile/kernels/`` (forward *and* backward via ``jax.custom_vjp``), so the
whole training step lowers into a single HLO module.

This module is build-time only: ``compile/aot.py`` lowers ``init_fn`` /
``train_step`` / ``eval_step`` to HLO text artifacts that the Rust runtime
(`rust/src/runtime/`) loads and executes. Python is never on the training
path.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import conv2d as kconv
from .kernels import matmul as kmat
from .kernels import pool as kpool


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    """Network-scale configuration (paper Table 2 vocabulary).

    ``conv_layers``/``filters`` ↔ "layers(Conv)"/"filters(Conv)";
    ``fc_layers``/``fc_neurons`` ↔ "layers(FC)"/"neurons(FC)". ``fc_layers``
    counts hidden layers; the class-logit layer is always appended.
    """

    name: str = "e2e"
    input_hw: int = 16
    in_channels: int = 1
    conv_layers: int = 2
    filters: int = 8
    kernel_hw: int = 3
    fc_layers: int = 2
    fc_neurons: int = 64
    num_classes: int = 10
    batch_size: int = 32
    pool_window: int = 2
    learning_rate: float = 0.05  # default η of Eq. 23 (runtime passes its own)

    def param_shapes(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Flattened parameter manifest: ordered (name, shape) pairs.

        The Rust coordinator treats the weight set as an ordered list of
        tensors; this order IS the wire format between L3 and the artifacts.
        """
        shapes: List[Tuple[str, Tuple[int, ...]]] = []
        c = self.in_channels
        k = self.kernel_hw
        for i in range(self.conv_layers):
            shapes.append((f"conv{i}.filter", (k, k, c, self.filters)))
            shapes.append((f"conv{i}.bias", (self.filters,)))
            c = self.filters
        hw = self.input_hw // self.pool_window
        fan_in = hw * hw * c
        for i in range(self.fc_layers):
            shapes.append((f"fc{i}.weight", (fan_in, self.fc_neurons)))
            shapes.append((f"fc{i}.bias", (self.fc_neurons,)))
            fan_in = self.fc_neurons
        shapes.append(("out.weight", (fan_in, self.num_classes)))
        shapes.append(("out.bias", (self.num_classes,)))
        return shapes

    def param_count(self) -> int:
        total = 0
        for _, shape in self.param_shapes():
            n = 1
            for d in shape:
                n *= d
            total += n
        return total


# Named configurations compiled to artifacts by compile/aot.py.
CONFIGS = {
    # Minimal config for the quickstart example and runtime smoke tests.
    "quickstart": CNNConfig(
        name="quickstart",
        input_hw=8,
        conv_layers=1,
        filters=4,
        fc_layers=1,
        fc_neurons=32,
        batch_size=8,
    ),
    # The end-to-end training workload (examples/train_e2e.rs, Fig. 11).
    "e2e": CNNConfig(name="e2e"),
}


def table2_config(case: int) -> CNNConfig:
    """Paper Table 2 network-scale cases 1–7 (used by the Fig. 14a sweep)."""
    layers_conv = [2, 4, 6, 8, 8, 10, 10]
    filters_conv = [4, 4, 8, 8, 10, 10, 12]
    layers_fc = [3, 3, 5, 5, 7, 7, 7]
    neurons_fc = [500, 1000, 1500, 1500, 2000, 2000, 2000]
    i = case - 1
    return CNNConfig(
        name=f"case{case}",
        input_hw=16,
        conv_layers=layers_conv[i],
        filters=filters_conv[i],
        fc_layers=layers_fc[i],
        fc_neurons=neurons_fc[i],
    )


def _pad_same(x: jax.Array, k: int) -> jax.Array:
    """Zero padding P = (k-1)//2 per Eq. (12) so H_a = H_x (SAME, stride 1)."""
    p = (k - 1) // 2
    return jnp.pad(x, ((0, 0), (p, p), (p, p), (0, 0)))


def init_params(cfg: CNNConfig, seed: jax.Array) -> List[jax.Array]:
    """He-scaled normal init, traceable in ``seed`` so it lowers to HLO."""
    key = jax.random.PRNGKey(seed)
    params: List[jax.Array] = []
    for pname, shape in cfg.param_shapes():
        key, sub = jax.random.split(key)
        if pname.endswith(".bias"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            std = jnp.sqrt(2.0 / fan_in)
            params.append(jax.random.normal(sub, shape, jnp.float32) * std)
    return params


def forward(cfg: CNNConfig, params: Sequence[jax.Array], x: jax.Array) -> jax.Array:
    """Forward pass → class logits. ``x``: (B, H, W, C_in)."""
    k = 0
    for _ in range(cfg.conv_layers):
        f, b = params[k], params[k + 1]
        k += 2
        x = _pad_same(x, cfg.kernel_hw)
        x = kconv.conv2d(x, f, b)  # Pallas fwd + bwd (Eq. 1)
        x = jnp.maximum(x, 0.0)
    x = kpool.mean_pool(x, cfg.pool_window)  # Pallas pooling
    bsz = x.shape[0]
    x = x.reshape(bsz, -1)
    for _ in range(cfg.fc_layers):
        w, b = params[k], params[k + 1]
        k += 2
        x = jnp.maximum(kmat.fc(x, w, b), 0.0)  # Pallas FC
    w, b = params[k], params[k + 1]
    return kmat.fc(x, w, b)


def loss_and_correct(
    cfg: CNNConfig, params: Sequence[jax.Array], x: jax.Array, y: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Square error of the output layer (Eq. 16) + correct-count.

    ``y``: one-hot labels (B, num_classes). The output activation is softmax
    so the squared error is bounded and the argmax matches the classifier
    decision; loss is averaged over the batch.
    """
    logits = forward(cfg, params, x)
    probs = jax.nn.softmax(logits, axis=-1)
    loss = jnp.sum((y - probs) ** 2) / x.shape[0]
    correct = jnp.sum(
        (jnp.argmax(logits, axis=-1) == jnp.argmax(y, axis=-1)).astype(jnp.float32)
    )
    return loss, correct


def train_step(
    cfg: CNNConfig,
    params: Sequence[jax.Array],
    x: jax.Array,
    y: jax.Array,
    lr: jax.Array,
) -> Tuple[List[jax.Array], jax.Array, jax.Array]:
    """One SGD step (Eq. 23): returns (updated params…, loss, correct)."""

    def objective(ps):
        loss, correct = loss_and_correct(cfg, ps, x, y)
        return loss, correct

    (loss, correct), grads = jax.value_and_grad(objective, has_aux=True)(list(params))
    new_params = [p - lr * g for p, g in zip(params, grads)]
    return new_params, loss, correct


def eval_step(
    cfg: CNNConfig, params: Sequence[jax.Array], x: jax.Array, y: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Evaluation: (loss, correct) on one batch without updating weights."""
    return loss_and_correct(cfg, params, x, y)
