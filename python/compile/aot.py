"""AOT compilation driver: lower the L2 model to HLO-text artifacts.

Usage: ``cd python && python -m compile.aot --out ../artifacts [--configs e2e,quickstart]``

Per config this writes::

    artifacts/<name>/init.hlo.txt        seed:i32                      -> (params…)
    artifacts/<name>/train_step.hlo.txt  (params…, x, y, lr)           -> (params…, loss, correct)
    artifacts/<name>/eval_step.hlo.txt   (params…, x, y)               -> (loss, correct)
    artifacts/<name>/meta.json           shapes / manifest for the Rust runtime

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md). Lowered with
``return_tuple=True``; the Rust side unwraps the tuple.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_config(cfg: M.CNNConfig, out_dir: str) -> dict:
    """Lower all three entry points for one config; return its manifest."""
    os.makedirs(out_dir, exist_ok=True)
    shapes = cfg.param_shapes()
    param_specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in shapes]
    x_spec = jax.ShapeDtypeStruct(
        (cfg.batch_size, cfg.input_hw, cfg.input_hw, cfg.in_channels), jnp.float32
    )
    y_spec = jax.ShapeDtypeStruct((cfg.batch_size, cfg.num_classes), jnp.float32)
    lr_spec = jax.ShapeDtypeStruct((), jnp.float32)
    seed_spec = jax.ShapeDtypeStruct((), jnp.int32)

    def init_fn(seed):
        return tuple(M.init_params(cfg, seed))

    def train_fn(*args):
        params = list(args[: len(shapes)])
        x, y, lr = args[len(shapes) :]
        new_params, loss, correct = M.train_step(cfg, params, x, y, lr)
        return (*new_params, loss, correct)

    def eval_fn(*args):
        params = list(args[: len(shapes)])
        x, y = args[len(shapes) :]
        return M.eval_step(cfg, params, x, y)

    entries = {
        "init": (init_fn, [seed_spec]),
        "train_step": (train_fn, [*param_specs, x_spec, y_spec, lr_spec]),
        "eval_step": (eval_fn, [*param_specs, x_spec, y_spec]),
    }
    for name, (fn, specs) in entries.items():
        text = to_hlo_text(jax.jit(fn).lower(*specs))
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        print(f"  wrote {path} ({len(text)} chars)")

    manifest = {
        "config": {
            "name": cfg.name,
            "input_hw": cfg.input_hw,
            "in_channels": cfg.in_channels,
            "conv_layers": cfg.conv_layers,
            "filters": cfg.filters,
            "kernel_hw": cfg.kernel_hw,
            "fc_layers": cfg.fc_layers,
            "fc_neurons": cfg.fc_neurons,
            "num_classes": cfg.num_classes,
            "batch_size": cfg.batch_size,
            "pool_window": cfg.pool_window,
        },
        "params": [{"name": n, "shape": list(s)} for n, s in shapes],
        "param_count": cfg.param_count(),
        "entries": {
            "init": {"inputs": ["seed:i32[]"], "outputs": len(shapes)},
            "train_step": {
                "inputs": len(shapes),
                "extra_inputs": ["x", "y", "lr"],
                "outputs": len(shapes) + 2,
            },
            "eval_step": {
                "inputs": len(shapes),
                "extra_inputs": ["x", "y"],
                "outputs": 2,
            },
        },
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as fh:
        json.dump(manifest, fh, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument(
        "--configs",
        default="quickstart,e2e",
        help="comma-separated config names from model.CONFIGS",
    )
    args = ap.parse_args()
    for name in args.configs.split(","):
        cfg = M.CONFIGS[name.strip()]
        print(f"lowering config '{cfg.name}' ({cfg.param_count()} params)…")
        lower_config(cfg, os.path.join(args.out, cfg.name))
    print("AOT done.")


if __name__ == "__main__":
    main()
