"""Layer-1 Pallas matmul kernel for the fully-connected classifier layers.

A straightforward MXU-tiled matmul: the grid walks row blocks of the batch;
each program computes ``x_block @ w + b``. For the paper's FC sizes
(≤2000×2000, Table 2) a single row block holds the whole batch in VMEM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dense_kernel(x_ref, w_ref, b_ref, o_ref):
    o_ref[...] = x_ref[...] @ w_ref[...] + b_ref[...]


def dense(x: jax.Array, w: jax.Array, b: jax.Array, *, block_m: int | None = None) -> jax.Array:
    """FC layer ``(B, I) @ (I, O) + (O,)`` as a Pallas kernel.

    ``block_m`` tiles the batch dimension (must divide B); ``None`` uses a
    single program.
    """
    m, k = x.shape
    _, n = w.shape
    out = jax.ShapeDtypeStruct((m, n), jnp.float32)
    if block_m is None:
        return pl.pallas_call(_dense_kernel, out_shape=out, interpret=True)(x, w, b)
    if m % block_m != 0:
        raise ValueError(f"block_m={block_m} must divide batch {m}")
    return pl.pallas_call(
        _dense_kernel,
        grid=(m // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, k), lambda g: (g, 0)),
            pl.BlockSpec((k, n), lambda g: (0, 0)),
            pl.BlockSpec((n,), lambda g: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m, n), lambda g: (g, 0)),
        out_shape=out,
        interpret=True,
    )(x, w, b)


def vmem_bytes(block_m: int, k: int, n: int) -> int:
    """Estimated VMEM working set of one program (f32) for §Perf."""
    return (block_m * k + k * n + n + block_m * n) * 4


@jax.custom_vjp
def fc(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Differentiable FC layer whose forward and backward are Pallas matmuls.

    Backward per §4.1.2: ``dx = dy @ wᵀ`` (Eq. 18 analogue for dense layers),
    ``dw = xᵀ @ dy`` (Eq. 21 analogue), ``db = Σ dy`` (Eq. 22).
    """
    return dense(x, w, b)


def _fc_vjp_fwd(x, w, b):
    return dense(x, w, b), (x, w)


def _fc_vjp_bwd(res, dy):
    x, w = res
    zeros_i = jnp.zeros((w.shape[0],), jnp.float32)
    zeros_o = jnp.zeros((dy.shape[1],), jnp.float32)
    dx = dense(dy, w.T, zeros_i)  # (B, O) @ (O, I)
    dw = dense(x.T, dy, zeros_o)  # (I, B) @ (B, O)
    db = dy.sum(axis=0)
    return dx, dw, db


fc.defvjp(_fc_vjp_fwd, _fc_vjp_bwd)
