"""Pure-jnp reference oracles for the Pallas kernels (Layer 1).

Every kernel in this package is checked against these functions by
``python/tests/test_kernel.py`` (pytest + hypothesis). The references are
written with ``jax.lax`` / ``jnp`` primitives only — no Pallas — so they
exercise an entirely independent lowering path.

Layouts
-------
* images:  NHWC  ``(N, H, W, C)``
* filters: HWIO  ``(KH, KW, C_in, C_out)`` — matches Eq. (1) of the paper
  (per-filter depth = input depth).
* FC:      ``(B, I) @ (I, O) + (O,)``
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d(x: jax.Array, f: jax.Array) -> jax.Array:
    """VALID convolution (stride 1), Eq. (1)/(12) of the paper.

    ``x``: (N, H, W, C); ``f``: (KH, KW, C, O) → (N, H-KH+1, W-KW+1, O).
    """
    return jax.lax.conv_general_dilated(
        x,
        f,
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def conv2d_same(x: jax.Array, f: jax.Array) -> jax.Array:
    """SAME convolution (stride 1): output spatial dims equal input's."""
    return jax.lax.conv_general_dilated(
        x,
        f,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def conv2d_filter_grad(x: jax.Array, dy: jax.Array, kh: int, kw: int) -> jax.Array:
    """Gradient of VALID conv w.r.t. the filter — Eq. (21) of the paper.

    ``x``: (N, H, W, C); ``dy``: (N, H-kh+1, W-kw+1, O) → (kh, kw, C, O).
    """
    _, vjp = jax.vjp(
        lambda f: conv2d(x, f),
        jnp.zeros((kh, kw, x.shape[3], dy.shape[3]), x.dtype),
    )
    return vjp(dy)[0]


def conv2d_input_grad(dy: jax.Array, f: jax.Array, h: int, w: int) -> jax.Array:
    """Gradient of VALID conv w.r.t. the input — Eq. (18) of the paper.

    Equivalent to a FULL convolution of ``dy`` with the spatially-flipped,
    channel-transposed filter.
    """
    n = dy.shape[0]
    c = f.shape[2]
    _, vjp = jax.vjp(lambda x: conv2d(x, f), jnp.zeros((n, h, w, c), dy.dtype))
    return vjp(dy)[0]


def mean_pool(x: jax.Array, window: int = 2) -> jax.Array:
    """Non-overlapping mean pooling over (H, W)."""
    n, h, w, c = x.shape
    ho, wo = h // window, w // window
    x = x[:, : ho * window, : wo * window, :]
    x = x.reshape(n, ho, window, wo, window, c)
    return x.mean(axis=(2, 4))


def max_pool(x: jax.Array, window: int = 2) -> jax.Array:
    """Non-overlapping max pooling over (H, W)."""
    n, h, w, c = x.shape
    ho, wo = h // window, w // window
    x = x[:, : ho * window, : wo * window, :]
    x = x.reshape(n, ho, window, wo, window, c)
    return x.max(axis=(2, 4))


def mean_pool_grad(dy: jax.Array, window: int = 2) -> jax.Array:
    """Gradient of non-overlapping mean pooling (uniform spread)."""
    n, ho, wo, c = dy.shape
    g = dy[:, :, None, :, None, :] / float(window * window)
    g = jnp.broadcast_to(g, (n, ho, window, wo, window, c))
    return g.reshape(n, ho * window, wo * window, c)


def dense(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Fully-connected layer: (B, I) @ (I, O) + (O,)."""
    return x @ w + b


def relu(x: jax.Array) -> jax.Array:
    return jnp.maximum(x, 0.0)
