"""Layer-1 Pallas kernels for the convolutional layer — the paper's compute
hot-spot (§4.1.1: convolutional layers take >85% of training time).

Hardware adaptation (see DESIGN.md §Hardware-Adaptation)
--------------------------------------------------------
The paper decomposes a convolutional layer into ``K_C = H_a × W_a``
independent scalar tasks (Eqs. 13–14) scheduled onto CPU threads. On a TPU
that granularity would starve the MXU, so the kernel expresses the *same*
decomposition as a **shifted matmul**: for each filter offset ``(i, j)`` the
input window ``x[:, i:i+H_a, j:j+W_a, :]`` is flattened to a
``(N·H_a·W_a, C)`` matrix and multiplied with the ``(C, O)`` filter slice on
the MXU — every MXU output row is exactly one of the paper's Eq.-13 tasks.
The grid (batch tiles) plays the role of the paper's task queue, and the
BlockSpecs express the HBM→VMEM schedule the paper expressed with per-task
working sets.

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO that the
Rust runtime executes directly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv2d_fwd_kernel(x_ref, f_ref, b_ref, o_ref):
    """One program: VALID conv (stride 1) of a batch block via shifted matmul."""
    n, h, w, c = x_ref.shape
    kh, kw, _, co = f_ref.shape
    ho, wo = h - kh + 1, w - kw + 1
    x = x_ref[...]
    f = f_ref[...]
    acc = jnp.zeros((n * ho * wo, co), jnp.float32)
    # Static KH×KW loop: each iteration is one MXU matmul (the paper's K_C
    # tasks batched along the matmul M dimension).
    for i in range(kh):
        for j in range(kw):
            patch = x[:, i : i + ho, j : j + wo, :].reshape(n * ho * wo, c)
            acc = acc + patch @ f[i, j]
    acc = acc + b_ref[...]
    o_ref[...] = acc.reshape(n, ho, wo, co)


def conv2d_fwd(x: jax.Array, f: jax.Array, b: jax.Array, *, block_n: int | None = None) -> jax.Array:
    """VALID convolution + bias via the Pallas kernel.

    ``x``: (N, H, W, C); ``f``: (KH, KW, C, O); ``b``: (O,).
    ``block_n``: batch-tile size for the grid (must divide N). ``None`` runs a
    single program over the whole batch — appropriate when the working set
    fits VMEM (see :func:`vmem_bytes_fwd`).
    """
    n, h, w, c = x.shape
    kh, kw, _, co = f.shape
    ho, wo = h - kh + 1, w - kw + 1
    out_shape = jax.ShapeDtypeStruct((n, ho, wo, co), jnp.float32)
    if block_n is None:
        return pl.pallas_call(_conv2d_fwd_kernel, out_shape=out_shape, interpret=True)(x, f, b)
    if n % block_n != 0:
        raise ValueError(f"block_n={block_n} must divide batch {n}")
    grid = (n // block_n,)
    return pl.pallas_call(
        _conv2d_fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, h, w, c), lambda g: (g, 0, 0, 0)),
            pl.BlockSpec((kh, kw, c, co), lambda g: (0, 0, 0, 0)),
            pl.BlockSpec((co,), lambda g: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n, ho, wo, co), lambda g: (g, 0, 0, 0)),
        out_shape=out_shape,
        interpret=True,
    )(x, f, b)


def _conv2d_filter_grad_kernel(x_ref, dy_ref, df_ref):
    """dL/dF for VALID conv — Eq. (21): df[i,j] = patchᵀ(i,j) @ dy."""
    n, h, w, c = x_ref.shape
    kh, kw, _, co = df_ref.shape
    ho, wo = h - kh + 1, w - kw + 1
    x = x_ref[...]
    dy = dy_ref[...].reshape(n * ho * wo, co)
    for i in range(kh):
        for j in range(kw):
            patch = x[:, i : i + ho, j : j + wo, :].reshape(n * ho * wo, c)
            df_ref[i, j] = patch.T @ dy  # (C, O) MXU matmul
def conv2d_filter_grad(x: jax.Array, dy: jax.Array, kh: int, kw: int) -> jax.Array:
    """Pallas filter gradient: (KH, KW, C, O)."""
    c, co = x.shape[3], dy.shape[3]
    return pl.pallas_call(
        _conv2d_filter_grad_kernel,
        out_shape=jax.ShapeDtypeStruct((kh, kw, c, co), jnp.float32),
        interpret=True,
    )(x, dy)


def _conv2d_input_grad_kernel(dy_ref, f_ref, dx_ref):
    """dL/dX for VALID conv — Eq. (18): scatter-accumulate dy @ f[i,j]ᵀ."""
    kh, kw, c, co = f_ref.shape
    n, ho, wo, _ = dy_ref.shape
    f = f_ref[...]
    dy = dy_ref[...].reshape(n * ho * wo, co)
    h, w = ho + kh - 1, wo + kw - 1
    dx = jnp.zeros((n, h, w, c), jnp.float32)
    for i in range(kh):
        for j in range(kw):
            contrib = (dy @ f[i, j].T).reshape(n, ho, wo, c)
            dx = dx.at[:, i : i + ho, j : j + wo, :].add(contrib)
    dx_ref[...] = dx


def conv2d_input_grad(dy: jax.Array, f: jax.Array, h: int, w: int) -> jax.Array:
    """Pallas input gradient: (N, H, W, C)."""
    n = dy.shape[0]
    c = f.shape[2]
    return pl.pallas_call(
        _conv2d_input_grad_kernel,
        out_shape=jax.ShapeDtypeStruct((n, h, w, c), jnp.float32),
        interpret=True,
    )(dy, f)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def conv2d(x: jax.Array, f: jax.Array, b: jax.Array, block_n: int | None = None) -> jax.Array:
    """Differentiable VALID conv whose forward AND backward are Pallas kernels.

    The L2 model (``compile/model.py``) calls this so the whole training step
    lowers into a single HLO module with the kernels inlined.
    """
    return conv2d_fwd(x, f, b, block_n=block_n)


def _conv2d_vjp_fwd(x, f, b, block_n):
    return conv2d_fwd(x, f, b, block_n=block_n), (x, f)


def _conv2d_vjp_bwd(block_n, res, dy):
    x, f = res
    kh, kw, _, _ = f.shape
    _, h, w, _ = x.shape
    dx = conv2d_input_grad(dy, f, h, w)
    df = conv2d_filter_grad(x, dy, kh, kw)
    db = dy.sum(axis=(0, 1, 2))
    return dx, df, db


conv2d.defvjp(_conv2d_vjp_fwd, _conv2d_vjp_bwd)


def vmem_bytes_fwd(block_n: int, h: int, w: int, c: int, kh: int, kw: int, co: int) -> int:
    """Estimated VMEM working set of one forward program (f32).

    Used by the §Perf analysis in EXPERIMENTS.md to size ``block_n`` against
    the ~16 MiB VMEM budget of a real TPU core.
    """
    ho, wo = h - kh + 1, w - kw + 1
    x_bytes = block_n * h * w * c * 4
    f_bytes = kh * kw * c * co * 4
    acc_bytes = block_n * ho * wo * co * 4
    patch_bytes = block_n * ho * wo * c * 4  # one shifted view materialized
    return x_bytes + f_bytes + acc_bytes + patch_bytes


def mxu_flops_fwd(n: int, h: int, w: int, c: int, kh: int, kw: int, co: int) -> int:
    """MXU FLOPs of the forward kernel (2·M·K·N per shifted matmul)."""
    ho, wo = h - kh + 1, w - kw + 1
    return kh * kw * 2 * (n * ho * wo) * c * co
