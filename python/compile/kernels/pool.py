"""Layer-1 Pallas pooling kernels (paper §3.1: max / mean pooling layers).

The model uses mean pooling (differentiable with a uniform-spread gradient,
Eq.-18-style error propagation through the pooling layer); a max-pool forward
kernel is provided for completeness and benchmarked in the ablations.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mean_pool_kernel(window: int, x_ref, o_ref):
    n, h, w, c = x_ref.shape
    ho, wo = h // window, w // window
    x = x_ref[...][:, : ho * window, : wo * window, :]
    x = x.reshape(n, ho, window, wo, window, c)
    o_ref[...] = x.mean(axis=(2, 4))


def mean_pool_fwd(x: jax.Array, window: int = 2) -> jax.Array:
    """Non-overlapping mean pooling: (N, H, W, C) → (N, H//w, W//w, C)."""
    n, h, w, c = x.shape
    out = jax.ShapeDtypeStruct((n, h // window, w // window, c), jnp.float32)
    return pl.pallas_call(
        functools.partial(_mean_pool_kernel, window), out_shape=out, interpret=True
    )(x)


def _max_pool_kernel(window: int, x_ref, o_ref):
    n, h, w, c = x_ref.shape
    ho, wo = h // window, w // window
    x = x_ref[...][:, : ho * window, : wo * window, :]
    x = x.reshape(n, ho, window, wo, window, c)
    o_ref[...] = x.max(axis=(2, 4))


def max_pool_fwd(x: jax.Array, window: int = 2) -> jax.Array:
    """Non-overlapping max pooling: (N, H, W, C) → (N, H//w, W//w, C)."""
    n, h, w, c = x.shape
    out = jax.ShapeDtypeStruct((n, h // window, w // window, c), jnp.float32)
    return pl.pallas_call(
        functools.partial(_max_pool_kernel, window), out_shape=out, interpret=True
    )(x)


def _mean_pool_grad_kernel(window: int, dy_ref, dx_ref):
    n, ho, wo, c = dy_ref.shape
    g = dy_ref[...][:, :, None, :, None, :] / float(window * window)
    g = jnp.broadcast_to(g, (n, ho, window, wo, window, c))
    dx_ref[...] = g.reshape(n, ho * window, wo * window, c)


def mean_pool_grad(dy: jax.Array, window: int = 2) -> jax.Array:
    """Gradient of mean pooling (uniform spread back to the window)."""
    n, ho, wo, c = dy.shape
    out = jax.ShapeDtypeStruct((n, ho * window, wo * window, c), jnp.float32)
    return pl.pallas_call(
        functools.partial(_mean_pool_grad_kernel, window), out_shape=out, interpret=True
    )(dy)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def mean_pool(x: jax.Array, window: int = 2) -> jax.Array:
    """Differentiable mean pooling with Pallas forward and backward."""
    return mean_pool_fwd(x, window)


def _mean_pool_vjp_fwd(x, window):
    return mean_pool_fwd(x, window), None


def _mean_pool_vjp_bwd(window, _res, dy):
    return (mean_pool_grad(dy, window),)


mean_pool.defvjp(_mean_pool_vjp_fwd, _mean_pool_vjp_bwd)
