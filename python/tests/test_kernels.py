"""Layer-1 correctness: every Pallas kernel vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/seeds; explicit cases pin the shapes the artifacts
actually use. This is the CORE correctness signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d as kconv
from compile.kernels import matmul as kmat
from compile.kernels import pool as kpool
from compile.kernels import ref

SETTINGS = dict(deadline=None, max_examples=25)


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def _close(a, b, tol=1e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol)


# ---------------------------------------------------------------- conv fwd
@settings(**SETTINGS)
@given(
    n=st.integers(1, 5),
    hw=st.integers(4, 12),
    c=st.integers(1, 4),
    co=st.integers(1, 6),
    k=st.sampled_from([1, 3, 5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_fwd_matches_ref(n, hw, c, co, k, seed):
    if k > hw:
        k = 1
    x = _rand(seed, (n, hw, hw, c))
    f = _rand(seed + 1, (k, k, c, co))
    b = _rand(seed + 2, (co,))
    got = kconv.conv2d_fwd(x, f, b)
    want = ref.conv2d(x, f) + b
    _close(got, want)


@settings(**SETTINGS)
@given(
    blocks=st.integers(1, 4),
    block_n=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_fwd_gridded_matches_whole(blocks, block_n, seed):
    """Gridded (batch-tiled) kernel == single-program kernel (HBM→VMEM split
    must not change the numbers)."""
    n = blocks * block_n
    x = _rand(seed, (n, 8, 8, 2))
    f = _rand(seed + 1, (3, 3, 2, 4))
    b = _rand(seed + 2, (4,))
    _close(kconv.conv2d_fwd(x, f, b, block_n=block_n), kconv.conv2d_fwd(x, f, b))


def test_conv2d_fwd_block_must_divide_batch():
    x = _rand(0, (5, 8, 8, 1))
    f = _rand(1, (3, 3, 1, 2))
    b = jnp.zeros((2,))
    with pytest.raises(ValueError):
        kconv.conv2d_fwd(x, f, b, block_n=2)


def test_conv2d_identity_kernel():
    """1x1 identity filter reproduces the input exactly."""
    x = _rand(3, (2, 6, 6, 1))
    f = jnp.ones((1, 1, 1, 1), jnp.float32)
    b = jnp.zeros((1,))
    _close(kconv.conv2d_fwd(x, f, b), x)


# --------------------------------------------------------------- conv grads
@settings(**SETTINGS)
@given(
    n=st.integers(1, 4),
    hw=st.integers(5, 10),
    c=st.integers(1, 3),
    co=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_filter_grad_matches_ref(n, hw, c, co, seed):
    k = 3
    x = _rand(seed, (n, hw, hw, c))
    dy = _rand(seed + 1, (n, hw - k + 1, hw - k + 1, co))
    got = kconv.conv2d_filter_grad(x, dy, k, k)
    want = ref.conv2d_filter_grad(x, dy, k, k)
    _close(got, want)


@settings(**SETTINGS)
@given(
    n=st.integers(1, 4),
    hw=st.integers(5, 10),
    c=st.integers(1, 3),
    co=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_input_grad_matches_ref(n, hw, c, co, seed):
    k = 3
    f = _rand(seed, (k, k, c, co))
    dy = _rand(seed + 1, (n, hw - k + 1, hw - k + 1, co))
    got = kconv.conv2d_input_grad(dy, f, hw, hw)
    want = ref.conv2d_input_grad(dy, f, hw, hw)
    _close(got, want)


def test_conv2d_custom_vjp_matches_jax_autodiff():
    """grad through the Pallas custom_vjp == grad through lax.conv."""
    x = _rand(7, (3, 8, 8, 2))
    f = _rand(8, (3, 3, 2, 4))
    b = _rand(9, (4,))

    def loss_pallas(x, f, b):
        return jnp.sum(jnp.tanh(kconv.conv2d(x, f, b)))

    def loss_ref(x, f, b):
        return jnp.sum(jnp.tanh(ref.conv2d(x, f) + b))

    g1 = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, f, b)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(x, f, b)
    for a, b_ in zip(g1, g2):
        _close(a, b_)


# ------------------------------------------------------------------ pooling
@settings(**SETTINGS)
@given(
    n=st.integers(1, 4),
    hw=st.sampled_from([4, 6, 8, 12]),
    c=st.integers(1, 5),
    window=st.sampled_from([2, 3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mean_pool_matches_ref(n, hw, c, window, seed):
    x = _rand(seed, (n, hw, hw, c))
    _close(kpool.mean_pool_fwd(x, window), ref.mean_pool(x, window))


@settings(**SETTINGS)
@given(
    n=st.integers(1, 4),
    hw=st.sampled_from([4, 6, 8]),
    c=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_max_pool_matches_ref(n, hw, c, seed):
    x = _rand(seed, (n, hw, hw, c))
    _close(kpool.max_pool_fwd(x, 2), ref.max_pool(x, 2))


@settings(**SETTINGS)
@given(
    n=st.integers(1, 3),
    hw=st.sampled_from([2, 3, 4]),
    c=st.integers(1, 3),
    window=st.sampled_from([2, 3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mean_pool_grad_matches_ref(n, hw, c, window, seed):
    dy = _rand(seed, (n, hw, hw, c))
    _close(kpool.mean_pool_grad(dy, window), ref.mean_pool_grad(dy, window))


def test_mean_pool_custom_vjp_matches_autodiff():
    x = _rand(11, (2, 8, 8, 3))

    def loss_pallas(x):
        return jnp.sum(kpool.mean_pool(x, 2) ** 2)

    def loss_ref(x):
        return jnp.sum(ref.mean_pool(x, 2) ** 2)

    _close(jax.grad(loss_pallas)(x), jax.grad(loss_ref)(x))


def test_mean_pool_preserves_constant():
    """Pooling a constant field is the identity on values."""
    x = jnp.full((1, 4, 4, 2), 3.5, jnp.float32)
    out = kpool.mean_pool_fwd(x, 2)
    _close(out, jnp.full((1, 2, 2, 2), 3.5, jnp.float32))


# ------------------------------------------------------------------- matmul
@settings(**SETTINGS)
@given(
    m=st.integers(1, 16),
    k=st.integers(1, 32),
    n=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_ref(m, k, n, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    b = _rand(seed + 2, (n,))
    _close(kmat.dense(x, w, b), ref.dense(x, w, b))


@settings(**SETTINGS)
@given(
    blocks=st.integers(1, 4),
    block_m=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_gridded_matches_whole(blocks, block_m, seed):
    m = blocks * block_m
    x = _rand(seed, (m, 12))
    w = _rand(seed + 1, (12, 7))
    b = _rand(seed + 2, (7,))
    _close(kmat.dense(x, w, b, block_m=block_m), kmat.dense(x, w, b))


def test_fc_custom_vjp_matches_autodiff():
    x = _rand(21, (4, 10))
    w = _rand(22, (10, 6))
    b = _rand(23, (6,))

    def loss_pallas(x, w, b):
        return jnp.sum(jnp.sin(kmat.fc(x, w, b)))

    def loss_ref(x, w, b):
        return jnp.sum(jnp.sin(ref.dense(x, w, b)))

    g1 = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, w, b)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, b_ in zip(g1, g2):
        _close(a, b_)


# --------------------------------------------------------- perf-model sanity
def test_vmem_estimate_monotone_in_block():
    a = kconv.vmem_bytes_fwd(1, 16, 16, 8, 3, 3, 8)
    b = kconv.vmem_bytes_fwd(8, 16, 16, 8, 3, 3, 8)
    assert b > a


def test_mxu_flops_formula():
    # 1 batch, 3x3 kernel over 8x8 (6x6 out), C=2, O=4:
    # 9 matmuls of (36x2)@(2x4) → 9 * 2*36*2*4 FLOPs
    assert kconv.mxu_flops_fwd(1, 8, 8, 2, 3, 3, 4) == 9 * 2 * 36 * 2 * 4
