"""Layer-2 correctness: model shapes, gradients, training dynamics, Table-2
configurations."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

SETTINGS = dict(deadline=None, max_examples=10)


def _batch(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, ky = jax.random.split(key)
    x = jax.random.normal(kx, (cfg.batch_size, cfg.input_hw, cfg.input_hw, cfg.in_channels))
    labels = jax.random.randint(ky, (cfg.batch_size,), 0, cfg.num_classes)
    y = jax.nn.one_hot(labels, cfg.num_classes)
    return x, y


def test_param_shapes_order_and_count():
    cfg = M.CONFIGS["e2e"]
    shapes = cfg.param_shapes()
    # conv params first, in layer order, weight-then-bias
    assert shapes[0][0] == "conv0.filter"
    assert shapes[1][0] == "conv0.bias"
    assert shapes[-2][0] == "out.weight"
    assert shapes[-1][0] == "out.bias"
    assert len(shapes) == 2 * (cfg.conv_layers + cfg.fc_layers + 1)
    assert cfg.param_count() == sum(int(np.prod(s)) for _, s in shapes)


def test_init_params_match_manifest():
    cfg = M.CONFIGS["quickstart"]
    params = M.init_params(cfg, jnp.int32(0))
    shapes = cfg.param_shapes()
    assert len(params) == len(shapes)
    for p, (_, s) in zip(params, shapes):
        assert p.shape == s
        assert p.dtype == jnp.float32


def test_init_biases_zero_weights_scaled():
    cfg = M.CONFIGS["quickstart"]
    params = M.init_params(cfg, jnp.int32(7))
    for p, (name, _) in zip(params, cfg.param_shapes()):
        if name.endswith(".bias"):
            assert float(jnp.abs(p).max()) == 0.0
        else:
            assert float(jnp.abs(p).max()) > 0.0


def test_init_deterministic_in_seed():
    cfg = M.CONFIGS["quickstart"]
    a = M.init_params(cfg, jnp.int32(3))
    b = M.init_params(cfg, jnp.int32(3))
    c = M.init_params(cfg, jnp.int32(4))
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))
    assert any(not np.array_equal(np.asarray(pa), np.asarray(pc)) for pa, pc in zip(a, c))


def test_forward_shapes():
    cfg = M.CONFIGS["quickstart"]
    params = M.init_params(cfg, jnp.int32(0))
    x, _ = _batch(cfg)
    logits = M.forward(cfg, params, x)
    assert logits.shape == (cfg.batch_size, cfg.num_classes)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_loss_nonnegative_and_bounded(seed):
    cfg = M.CONFIGS["quickstart"]
    params = M.init_params(cfg, jnp.int32(seed % 100))
    x, y = _batch(cfg, seed)
    loss, correct = M.eval_step(cfg, params, x, y)
    # Square error of softmax vs one-hot is in [0, 2] per sample (Eq. 16).
    assert 0.0 <= float(loss) <= 2.0
    assert 0.0 <= float(correct) <= cfg.batch_size


def test_train_step_reduces_loss_on_fixed_batch():
    """Repeated SGD on one batch must overfit it (Eq. 23 sanity)."""
    cfg = M.CONFIGS["quickstart"]
    params = M.init_params(cfg, jnp.int32(0))
    x, y = _batch(cfg, seed=1)
    first_loss = None
    loss = None
    for _ in range(30):
        params, loss, _ = M.train_step(cfg, params, x, y, jnp.float32(0.5))
        if first_loss is None:
            first_loss = float(loss)
    assert float(loss) < 0.7 * first_loss


def test_train_step_grad_matches_finite_differences():
    cfg = M.CNNConfig(
        name="fd", input_hw=6, conv_layers=1, filters=2, fc_layers=1,
        fc_neurons=8, num_classes=3, batch_size=2,
    )
    params = M.init_params(cfg, jnp.int32(5))
    x, y = _batch(cfg, seed=2)

    def loss_of(ps):
        loss, _ = M.eval_step(cfg, ps, x, y)
        return float(loss)

    grads = jax.grad(lambda ps: M.eval_step(cfg, ps, x, y)[0])(params)
    # Check a handful of coordinates of the first conv filter by central FD.
    p0 = np.asarray(params[0]).copy()
    g0 = np.asarray(grads[0])
    eps = 1e-3
    for idx in [(0, 0, 0, 0), (1, 1, 0, 1), (2, 2, 0, 0)]:
        pp = [p.copy() for p in params]
        pm = [p.copy() for p in params]
        ap = p0.copy()
        ap[idx] += eps
        am = p0.copy()
        am[idx] -= eps
        pp[0] = jnp.asarray(ap)
        pm[0] = jnp.asarray(am)
        fd = (loss_of(pp) - loss_of(pm)) / (2 * eps)
        assert abs(fd - g0[idx]) < 5e-3, f"FD mismatch at {idx}: {fd} vs {g0[idx]}"


def test_eval_step_does_not_modify_params():
    cfg = M.CONFIGS["quickstart"]
    params = M.init_params(cfg, jnp.int32(0))
    before = [np.asarray(p).copy() for p in params]
    x, y = _batch(cfg)
    M.eval_step(cfg, params, x, y)
    for b, p in zip(before, params):
        np.testing.assert_array_equal(b, np.asarray(p))


@pytest.mark.parametrize("case", range(1, 8))
def test_table2_configs(case):
    """Table 2 cases 1–7 are well-formed and monotonically larger."""
    cfg = M.table2_config(case)
    assert cfg.conv_layers in (2, 4, 6, 8, 10)
    assert cfg.param_shapes()  # constructible
    if case > 1:
        assert M.table2_config(case).param_count() >= M.table2_config(case - 1).param_count()


def test_table2_case1_matches_paper_row():
    cfg = M.table2_config(1)
    assert (cfg.conv_layers, cfg.filters, cfg.fc_layers, cfg.fc_neurons) == (2, 4, 3, 500)


def test_table2_case7_matches_paper_row():
    cfg = M.table2_config(7)
    assert (cfg.conv_layers, cfg.filters, cfg.fc_layers, cfg.fc_neurons) == (10, 12, 7, 2000)
