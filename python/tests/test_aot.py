"""AOT pipeline: lowering produces valid HLO text and a manifest consistent
with the model's parameter layout (the L3 wire format)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model as M


def test_to_hlo_text_roundtrips_simple_fn():
    def fn(x):
        return (x * 2.0 + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[4]" in text


def test_lower_config_writes_all_artifacts(tmp_path):
    cfg = M.CNNConfig(
        name="tiny", input_hw=6, conv_layers=1, filters=2, fc_layers=1,
        fc_neurons=8, num_classes=3, batch_size=2,
    )
    manifest = aot.lower_config(cfg, str(tmp_path))
    for entry in ("init", "train_step", "eval_step"):
        path = tmp_path / f"{entry}.hlo.txt"
        assert path.exists()
        text = path.read_text()
        assert "ENTRY" in text
    meta = json.loads((tmp_path / "meta.json").read_text())
    assert meta == manifest
    assert meta["param_count"] == cfg.param_count()
    assert len(meta["params"]) == len(cfg.param_shapes())


def test_train_step_hlo_signature_matches_manifest(tmp_path):
    """The HLO entry computation must take P+3 parameters and return a
    (P+2)-tuple — this is the contract rust/src/runtime depends on."""
    cfg = M.CNNConfig(
        name="tiny", input_hw=6, conv_layers=1, filters=2, fc_layers=1,
        fc_neurons=8, num_classes=3, batch_size=2,
    )
    aot.lower_config(cfg, str(tmp_path))
    text = (tmp_path / "train_step.hlo.txt").read_text()
    p = len(cfg.param_shapes())
    # Count 'parameter(k)' occurrences in the entry computation.
    n_params = sum(1 for i in range(p + 4) if f"parameter({i})" in text)
    assert n_params == p + 3, f"expected {p + 3} HLO parameters, found {n_params}"


def test_lowered_train_step_executes_and_matches_eager(tmp_path):
    """Compile the lowered StableHLO (same path the artifacts take) and check
    it produces the same numbers as eager execution."""
    cfg = M.CNNConfig(
        name="tiny", input_hw=6, conv_layers=1, filters=2, fc_layers=1,
        fc_neurons=8, num_classes=3, batch_size=2,
    )
    shapes = cfg.param_shapes()
    params = M.init_params(cfg, jnp.int32(0))
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (cfg.batch_size, cfg.input_hw, cfg.input_hw, 1))
    y = jax.nn.one_hot(jnp.arange(cfg.batch_size) % cfg.num_classes, cfg.num_classes)
    lr = jnp.float32(0.1)

    def train_fn(*args):
        ps = list(args[: len(shapes)])
        xx, yy, l = args[len(shapes):]
        new_params, loss, correct = M.train_step(cfg, ps, xx, yy, l)
        return (*new_params, loss, correct)

    eager = train_fn(*params, x, y, lr)
    jitted = jax.jit(train_fn)(*params, x, y, lr)
    for a, b in zip(eager, jitted):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_checked_in_artifacts_if_present():
    """If `make artifacts` has run, validate the manifests on disk."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    if not os.path.isdir(root):
        return
    for name in os.listdir(root):
        meta_path = os.path.join(root, name, "meta.json")
        if not os.path.exists(meta_path):
            continue
        with open(meta_path) as fh:
            meta = json.load(fh)
        cfg = M.CONFIGS.get(name)
        if cfg is None:
            continue
        assert meta["param_count"] == cfg.param_count()
        assert [tuple(p["shape"]) for p in meta["params"]] == [
            s for _, s in cfg.param_shapes()
        ]
